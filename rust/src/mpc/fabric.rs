//! The secure-compute fabric: one facade over Paillier aggregation,
//! share conversion and garbled-circuit matrix algebra, with two
//! interchangeable backends.
//!
//! * [`RealFabric`] — everything executed for real: Paillier ciphertexts,
//!   blind decryption, IKNP OT, streamed half-gates garbling between the
//!   two Center server threads.
//! * [`ModelFabric`] — identical numerics in plaintext (quantized to the
//!   same fixed-point grid), with a virtual clock advanced by *exact*
//!   operation counts (from [`CountBackend`]) times calibrated
//!   per-primitive costs ([`CostModel`]). Used for the paper's
//!   SimuX100–SimuX400 scales, which ran for hours-to-days even on the
//!   authors' testbed. Every report labels the backend used.
//!
//! Protocol code (`crate::protocols`) is written once against
//! [`SecureFabric`], so both backends run the *same* protocol logic.

use std::collections::HashMap;
use std::sync::{Arc, OnceLock};
use std::time::Instant;

use super::circuits::{
    tri_idx, tri_len, CholeskyShareProg, ConvergedProg, InverseMaskedProg, NewtonStepProg,
    SolveProg, SIGMA,
};
use super::costmodel::{CostLedger, CostModel};
use super::peer::{execute_local, PeerGcClient, ProgSpec};
use crate::bigint::{BigInt, BigUint, Montgomery, RandomSource, StrausTable};
use crate::coordinator::fleet::FleetKey;
use crate::crypto::fixed::FixedCodec;
use crate::crypto::packed::{PackError, PackedCodec, PackedMeta, BLIND_SIGMA};
use crate::crypto::paillier::{ChaChaSource, Ciphertext, Keypair, PublicKey};
use crate::crypto::rng::ChaChaRng;
use crate::gc::backend::CountBackend;
use crate::gc::exec::{ExecStats, GcProgram, GcSession};
use crate::gc::word::FixedFmt;
use crate::linalg::Matrix;
use crate::net::wire;
use crate::obs;
use crate::runtime::pool;

// The packed share conversion draws its per-slot blinds below
// `2^(w + ⌈log₂(parts+1)⌉ + σ)` with the *same* statistical-hiding σ as
// the unpacked conversion's `2^(w+σ)` bound; the two constants live in
// different layers of the module DAG, so pin them together here.
const _: () = assert!(BLIND_SIGMA as usize == SIGMA);

/// Both additive halves of one value mod 2^w in a single hand. This is a
/// **test/driver helper type only** (see [`share_vec`]): the fabric's own
/// share custody is [`ShareVec`], which keeps S2's halves either inline
/// (in-process simulation) or at the remote center-b — never recombined.
#[derive(Clone, Copy, Debug)]
pub struct Shared {
    /// S1's share.
    pub a: u128,
    /// S2's share.
    pub b: u128,
}

/// Where Center server S2's halves of a shared vector live.
#[derive(Clone, Debug)]
pub enum S2Custody {
    /// In-process simulation (`Mem` / `TcpLoopback` center links): both
    /// servers are threads of this process, so S2's halves sit right
    /// here. Same trust shape as before the split — one logical party.
    Local(Vec<u128>),
    /// Split custody: the remote `privlogit center-b` process holds its
    /// halves under this session handle. The values never crossed the
    /// peer wire; S1 only ever sees the opaque handle (the element
    /// count lives in the sibling `a` vector — one source of truth).
    Remote {
        /// Session-scoped handle center-b stores the halves under.
        handle: u64,
    },
}

/// S1's view of a secret-shared vector: its own additive shares plus
/// custody information for S2's halves. Protocol code treats this as an
/// opaque token; only the fabric (and center-b) touch the halves.
#[derive(Clone, Debug)]
pub struct ShareVec {
    /// S1's shares, one w-bit word per element.
    pub a: Vec<u128>,
    /// Custody of S2's halves.
    pub b: S2Custody,
}

impl ShareVec {
    /// Number of shared values.
    pub fn len(&self) -> usize {
        self.a.len()
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.a.is_empty()
    }
}

/// A vector of secret-shared values (or their modeled plaintext).
#[derive(Clone, Debug)]
pub enum SecVec {
    /// Real additive shares (S1's halves + S2 custody).
    Shares(ShareVec),
    /// Cost-model backend: plaintext values on the fixed-point grid.
    Model(Vec<f64>),
}

impl SecVec {
    /// Number of values.
    pub fn len(&self) -> usize {
        match self {
            SecVec::Shares(v) => v.len(),
            SecVec::Model(v) => v.len(),
        }
    }

    /// True iff empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// A vector of Paillier ciphertexts (or their modeled plaintext), tagged
/// with the fixed-point scale of the encoded values.
#[derive(Clone, Debug)]
pub struct EncVec {
    /// Fixed-point scale (bits) of the plaintexts.
    pub scale: u32,
    /// Slot-packing metadata when each plaintext carries multiple values
    /// in radix-`2^b` slots ([`crate::crypto::packed`]); `None` for the
    /// one-value-per-plaintext legacy layout.
    pub packed: Option<PackedMeta>,
    /// Payload.
    pub data: EncData,
}

/// Encrypted payload per backend.
#[derive(Clone, Debug)]
pub enum EncData {
    /// Real Paillier ciphertexts.
    Real(Vec<Ciphertext>),
    /// Modeled plaintexts.
    Model(Vec<f64>),
}

impl EncVec {
    /// Number of ciphertexts (a packed vector carries
    /// `⌈logical_len / k⌉` of them).
    pub fn len(&self) -> usize {
        match &self.data {
            EncData::Real(v) => v.len(),
            EncData::Model(v) => v.len(),
        }
    }

    /// Logical number of encoded values: the packed length when
    /// slot-packed, otherwise the ciphertext count.
    pub fn logical_len(&self) -> usize {
        match self.packed {
            Some(m) => m.len,
            None => self.len(),
        }
    }
}

/// An encrypted symmetric p×p matrix (packed lower triangle).
#[derive(Clone, Debug)]
pub struct EncMat {
    /// Dimensionality.
    pub p: usize,
    /// Packed lower triangle, scale-f ciphertexts.
    pub tri: EncVec,
}

/// Which GC program a cost lookup refers to (gate counts are
/// data-independent, so they cache perfectly).
#[derive(Clone, Copy, PartialEq, Eq, Hash, Debug)]
enum ProgKind {
    Newton(usize),
    Cholesky(usize),
    Solve(usize),
    Inverse(usize),
    Converged,
}

/// The protocol-facing secure-compute interface.
pub trait SecureFabric {
    /// Fixed-point format used throughout.
    fn fmt(&self) -> FixedFmt;

    /// The session's slot-packing layout, when the statistic fan-in is
    /// packed ([`crate::crypto::packed`]). Backends without packing
    /// return `None` and every packed-path branch is skipped.
    fn packing(&self) -> Option<PackedCodec> {
        None
    }

    // ---- node-side (Type-1, Paillier) ----

    /// Node `node` encrypts a statistics vector (scale f).
    fn node_encrypt_vec(&mut self, node: usize, vals: &[f64]) -> EncVec;
    /// Node computes `Enc(H̃⁻¹) ⊗ g_j` — multiply-by-constant rows, the
    /// PrivLogit-Local workhorse (Alg. 3 step 7). Result scale 2f.
    fn node_apply_hinv(&mut self, node: usize, hinv: &EncMat, gj: &[f64]) -> EncVec;

    // ---- center-side Paillier (S2, aggregation) ----

    /// `⊕`-aggregate per-node vectors (Alg. 1 step 8). Node-reply
    /// ciphertext vectors are wire-controlled data, so shape violations
    /// (scale or length mismatch, modeled payloads on the real backend)
    /// are session errors — one malformed node must not panic the
    /// center. With a remote center-b peer the parts are relayed without
    /// decryption and S2 performs the fold itself.
    fn aggregate(&mut self, parts: Vec<EncVec>) -> anyhow::Result<EncVec>;
    /// Homomorphically add a public plaintext vector (regularization
    /// terms; pass negated values for `⊖`). Fixed-point encoding of the
    /// plaintexts is fallible (non-finite / out-of-range values are
    /// session errors), and a packed input packs the plaintexts into
    /// the same slot layout — one more biased contribution per slot,
    /// rejected if it would exceed the negotiated fan-in bound.
    fn add_plain(&mut self, v: &EncVec, plain: &[f64]) -> anyhow::Result<EncVec>;
    /// Center-side `Enc(H̃⁻¹) ⊗ v` for the public regularization vector.
    fn center_apply_hinv(&mut self, hinv: &EncMat, v: &[f64]) -> EncVec;

    // ---- conversions ----

    /// Blind-convert ciphertexts (scale f) into additive shares mod 2^w.
    /// The input scale traces back to node replies, so a mismatch is a
    /// session error, not a panic. With a remote center-b peer, S2 draws
    /// the blinds ρ and keeps its own halves.
    fn to_shares(&mut self, v: &EncVec) -> anyhow::Result<SecVec>;
    /// Blind-decrypt values that the protocol *reveals by design*
    /// (the Newton step Δ / the coefficient update — paper §5.3).
    fn decrypt_reveal(&mut self, v: &EncVec) -> Vec<f64>;

    // ---- center-side GC (Type-2, between S1 and S2) ----

    /// One secure Newton step: Cholesky + solve, Δ revealed (baseline).
    fn newton_step(&mut self, h_tri: &SecVec, g: &SecVec, p: usize) -> Vec<f64>;
    /// `SetupOnce` Cholesky with re-shared output (PrivLogit-Hessian).
    fn cholesky_shares(&mut self, h_tri: &SecVec, p: usize) -> SecVec;
    /// Back-substitution on shared `L`, Δ revealed (PL-Hessian iteration).
    fn solve_reveal(&mut self, l_tri: &SecVec, g: &SecVec, p: usize) -> Vec<f64>;
    /// `H̃⁻¹` materialized as Paillier ciphertexts (PL-Local setup).
    fn inverse_to_enc(&mut self, h_tri: &SecVec, p: usize) -> EncMat;
    /// Secure relative-convergence check; only the bit is revealed.
    fn converged(&mut self, l_new: &SecVec, l_old: &SecVec, tol: f64) -> bool;

    // ---- accounting ----

    /// The cost ledger.
    fn ledger(&self) -> &CostLedger;
    /// Mutable ledger access (protocols close node rounds through this).
    fn ledger_mut(&mut self) -> &mut CostLedger;
    /// The network/cost model used for total-time reporting.
    fn cost_model(&self) -> &CostModel;
    /// Human-readable backend label for reports.
    fn backend_label(&self) -> &'static str;

    // ---- observability ----

    /// 64-bit trace session id. The real backend hashes the Paillier
    /// modulus ([`crate::obs::session_id`]) — every process holding the
    /// key material derives the *same* id with no extra wire traffic, so
    /// per-process traces join on it. The modeled backend has no key and
    /// stays at 0 (rendered as `-`).
    fn session_id(&self) -> u64 {
        0
    }

    /// Per-wire-tag control-frame accounting of the center peer link
    /// (empty in-process and on the modeled backend).
    fn peer_tag_flows(&self) -> std::collections::BTreeMap<u8, crate::obs::TagFlow> {
        std::collections::BTreeMap::new()
    }
}

// ======================================================================
// Real backend
// ======================================================================

/// The link to Center server S2 — both its garbled-circuit half **and**
/// its share custody. The fabric is S1's view; everything S2-side
/// (aggregation, blinding, evaluator inputs, share storage) happens
/// behind this seam, either inline (in-process simulation) or at a
/// remote `privlogit center-b` process.
pub enum ShareLink {
    /// Both halves in this process: a [`GcSession`] over scoped threads
    /// (in-memory queue or TCP loopback, depending on construction).
    /// S2's share halves travel inline as [`S2Custody::Local`].
    Local(GcSession),
    /// S2 is a remote `privlogit center-b` process reached over TCP
    /// (see [`crate::mpc::peer`]): it aggregates relayed node
    /// ciphertexts, draws its own blinds, stores its own share halves
    /// ([`S2Custody::Remote`]) and feeds them into the GC evaluator.
    Peer(PeerGcClient),
}

impl ShareLink {
    /// Bytes that crossed the center link so far. Both accessors return
    /// the *total over both directions* — `GcSession` sums its two
    /// endpoints' sent (resp. received) counters, and every byte one
    /// server sends the other receives, so sent-totals, received-totals
    /// and the peer client's `sent + received` are all the same number.
    fn bytes_transferred(&self) -> u64 {
        match self {
            ShareLink::Local(session) => session.bytes_transferred(),
            ShareLink::Peer(client) => client.bytes_sent() + client.bytes_received(),
        }
    }

    fn bytes_received(&self) -> u64 {
        match self {
            ShareLink::Local(session) => session.bytes_received(),
            ShareLink::Peer(client) => client.bytes_sent() + client.bytes_received(),
        }
    }
}

/// Which center-link transport [`RealFabric::build`] should establish.
enum LinkSpec<'a> {
    Mem,
    TcpLoopback,
    Peer { addr: &'a str, connect_timeout: std::time::Duration, epoch: u64 },
}

/// S2's inputs to one GC execution (see [`RealFabric::eval_input`]).
enum EvalInput {
    /// In-process: the evaluator bits themselves.
    Bits(Vec<bool>),
    /// Remote custody: center-b's stored share handles, in input order.
    Handles(Vec<u64>),
}

/// What came back from the masked-inverse execution: raw output bits
/// (in-process — this side plays S2 too) or the finished ciphertexts
/// (center-b encrypted its own wide outputs).
enum InverseOutcome {
    Bits(Vec<bool>),
    Cts(Vec<Ciphertext>),
}

/// Fully-executed backend: real Paillier, real OT, real garbling. This
/// is **S1's view** of the two-server Center — with a remote center-b
/// peer, S2's share halves and blinds exist only behind [`ShareLink`].
pub struct RealFabric {
    fmt: FixedFmt,
    kp: Keypair,
    codec: FixedCodec,
    link: ShareLink,
    rng: ChaChaRng,
    ledger: CostLedger,
    net: CostModel,
    label: &'static str,
    /// Next S2 share handle (peer link only; the driver allocates ids).
    next_handle: u64,
    /// Trace session id (hash of the Paillier modulus; see
    /// [`SecureFabric::session_id`]).
    session: u64,
    /// Per-span-name occurrence counters: the trace round join keys.
    /// Each tagged span name maps 1:1 to a peer control tag, and every
    /// such span sends exactly one frame of that tag, so these counters
    /// advance in lockstep with center-b's per-tag counters.
    span_rounds: std::collections::BTreeMap<&'static str, u64>,
    /// Straus-prepared `Enc(H̃⁻¹)`, keyed by the triangle it was built
    /// from — PrivLogit-Local applies the same broadcast triangle every
    /// iteration, so the window tables are built once, not per round.
    prepared_hinv: Option<(Vec<Ciphertext>, PreparedHinv)>,
    /// Slot-packing layout for the statistic fan-in, when enabled
    /// ([`RealFabric::enable_packing`]); `None` = unpacked legacy path.
    packing: Option<PackedCodec>,
}

impl RealFabric {
    /// Build a real fabric: generates the Paillier keypair (`modulus_bits`)
    /// and runs the GC base-OT phase over in-memory center channels.
    pub fn new(modulus_bits: usize, fmt: FixedFmt, seed: u64) -> Self {
        Self::build(modulus_bits, fmt, seed, LinkSpec::Mem)
            .expect("in-memory center link cannot fail")
    }

    /// Like [`RealFabric::new`], but the two Center servers talk over
    /// real TCP loopback sockets (the paper's two-PC testbed shape): all
    /// garbled tables, OT messages and decode bits cross the kernel
    /// network stack through the framed, CRC-checked wire format.
    pub fn new_tcp_loopback(
        modulus_bits: usize,
        fmt: FixedFmt,
        seed: u64,
    ) -> std::io::Result<Self> {
        Self::build(modulus_bits, fmt, seed, LinkSpec::TcpLoopback)
    }

    /// Like [`RealFabric::new`], but the GC evaluator (Center server S2)
    /// is a remote `privlogit center-b` process at `addr` — the paper's
    /// two-server Center as two genuinely separate OS processes.
    pub fn connect_peer(
        modulus_bits: usize,
        fmt: FixedFmt,
        seed: u64,
        addr: &str,
    ) -> std::io::Result<Self> {
        Self::connect_peer_with(
            modulus_bits,
            fmt,
            seed,
            addr,
            super::peer::PEER_CONNECT_TIMEOUT,
            0,
        )
    }

    /// [`RealFabric::connect_peer`] with the configured connect-retry
    /// budget (the fleet's `--connect-timeout` knob — peer and fleet
    /// links share it) and the session epoch a resuming center
    /// announces so S2's re-key guard matches the nodes'.
    pub fn connect_peer_with(
        modulus_bits: usize,
        fmt: FixedFmt,
        seed: u64,
        addr: &str,
        connect_timeout: std::time::Duration,
        epoch: u64,
    ) -> std::io::Result<Self> {
        Self::build(modulus_bits, fmt, seed, LinkSpec::Peer { addr, connect_timeout, epoch })
    }

    fn build(
        modulus_bits: usize,
        fmt: FixedFmt,
        seed: u64,
        link: LinkSpec<'_>,
    ) -> std::io::Result<Self> {
        let mut rng = ChaChaRng::from_u64_seed(seed);
        let t0 = Instant::now();
        let mut setup_span =
            obs::span("fabric.setup").u64("modulus_bits", modulus_bits as u64);
        let kp = Keypair::generate(modulus_bits, &mut rng);
        let session = obs::session_id(&kp.pk.n.to_bytes_le());
        setup_span.record_session(session);
        let codec = FixedCodec::new(kp.pk.n.clone(), fmt.f);
        let (link, label) = match link {
            LinkSpec::Mem => (
                ShareLink::Local(GcSession::new(seed ^ 0xFAB)),
                "real (Paillier + garbled circuits)",
            ),
            LinkSpec::TcpLoopback => {
                let (g, e) = crate::net::tcp::loopback_channel_pair()?;
                (
                    ShareLink::Local(GcSession::over_channels(g, e, seed ^ 0xFAB)),
                    "real (Paillier + garbled circuits; tcp center link)",
                )
            }
            LinkSpec::Peer { addr, connect_timeout, epoch } => {
                let mut client =
                    PeerGcClient::connect_with(addr, seed ^ 0xFAB, connect_timeout, epoch)?;
                // S2 needs the public key to aggregate, blind and
                // re-encrypt; only the modulus travels (public material).
                client.install_key(&kp.pk.n, fmt)?;
                (
                    ShareLink::Peer(client),
                    "real (Paillier + garbled circuits; remote center-b peer)",
                )
            }
        };
        let mut ledger = CostLedger::default();
        ledger.setup_secs += t0.elapsed().as_secs_f64();
        setup_span.done();
        Ok(RealFabric {
            fmt,
            kp,
            codec,
            link,
            rng,
            ledger,
            net: CostModel::load(CostModel::CALIBRATION_PATH),
            label,
            next_handle: 1,
            session,
            span_rounds: std::collections::BTreeMap::new(),
            prepared_hinv: None,
            packing: None,
        })
    }

    /// Enable slot-packing for the statistic fan-in: derive the layout
    /// from the session format, the fan-in bound `max_parts` (node
    /// count plus the center-side plain additions) and the worst-case
    /// constant-multiply width `apply_terms` (the model dimension `p`).
    /// Returns `true` when packing is on. A modulus too small to host
    /// two slots (`modulus_capacity`) falls back to the unpacked path
    /// with `Ok(false)`; any other violated headroom term is a real
    /// configuration error and surfaces as `Err` naming the term.
    pub fn enable_packing(&mut self, max_parts: u64, apply_terms: u64) -> Result<bool, PackError> {
        let modulus_bits = self.kp.pk.n.bit_len() as u32;
        match PackedCodec::plan(modulus_bits, self.fmt, max_parts, apply_terms) {
            Ok(codec) => {
                self.packing = Some(codec);
                Ok(true)
            }
            Err(PackError::Capacity { .. }) => {
                self.packing = None;
                Ok(false)
            }
            Err(e) => Err(e),
        }
    }

    /// Pack and encrypt a statistics vector under the session layout —
    /// the node-side packed encode path (the TCP node servers run the
    /// same codec over the wire-negotiated parameters; this inherent
    /// method serves the in-process fleets, tests and benches). Errors
    /// if packing is not enabled or a value exceeds the per-slot budget.
    pub fn encrypt_packed(&mut self, vals: &[f64]) -> anyhow::Result<EncVec> {
        let codec = self
            .packing
            .ok_or_else(|| anyhow::anyhow!("encrypt_packed without an enabled packing layout"))?;
        let ms = codec.pack(vals, self.fmt.f)?;
        let cts =
            self.kp.pk.encrypt_batch(&ms, &mut ChaChaSource(&mut self.rng), pool::threads());
        self.ledger.paillier_encs += cts.len() as u64;
        let sent: u64 = cts.iter().map(|c| c.byte_len() as u64).sum();
        self.ledger.bytes += sent;
        self.ledger.bytes_recv += sent; // the Center receives what nodes send
        Ok(EncVec {
            scale: self.fmt.f,
            packed: Some(codec.meta(vals.len())),
            data: EncData::Real(cts),
        })
    }

    /// The Paillier + fixed-point material node servers need to encrypt
    /// their statistic replies themselves (`Fleet::install_key`).
    pub fn fleet_key(&self) -> FleetKey {
        FleetKey {
            n: self.kp.pk.n.clone(),
            w: self.fmt.w as u32,
            f: self.fmt.f,
            packing: self.packing.map(|c| c.params()),
        }
    }

    fn bits_of_share(&self, v: u128) -> Vec<bool> {
        (0..self.fmt.w).map(|i| (v >> i) & 1 == 1).collect()
    }

    fn decode_out_words(&self, bits: &[bool]) -> Vec<f64> {
        bits.chunks(self.fmt.w)
            .map(|c| {
                let mut raw: i128 = 0;
                for (i, &b) in c.iter().enumerate() {
                    if b {
                        raw |= 1 << i;
                    }
                }
                self.fmt.decode(raw)
            })
            .collect()
    }

    fn expect_real<'a>(&self, v: &'a EncVec) -> &'a [Ciphertext] {
        match &v.data {
            EncData::Real(c) => c,
            EncData::Model(_) => panic!("model EncVec passed to RealFabric"),
        }
    }

    /// Like [`RealFabric::expect_real`], but for wire-controlled inputs
    /// (node-reply ciphertexts): a shape violation is a session error,
    /// never a center panic.
    fn real_cts<'a>(&self, v: &'a EncVec) -> anyhow::Result<&'a [Ciphertext]> {
        match &v.data {
            EncData::Real(c) => Ok(c),
            EncData::Model(_) => {
                anyhow::bail!("modeled ciphertext payload reached the real center backend")
            }
        }
    }

    fn expect_shares<'a>(&self, v: &'a SecVec) -> &'a ShareVec {
        match v {
            SecVec::Shares(s) => s,
            SecVec::Model(_) => panic!("model SecVec passed to RealFabric"),
        }
    }

    /// Local-custody S2 halves of `v` (in-process links only).
    fn local_b<'a>(&self, v: &'a ShareVec) -> &'a [u128] {
        match &v.b {
            S2Custody::Local(b) => b,
            S2Custody::Remote { .. } => panic!(
                "remote share handle met an in-process center link — \
                 shares from a peer session cannot be replayed locally"
            ),
        }
    }

    /// Remote handle of `v`'s S2 halves (peer link only).
    fn remote_handle(&self, v: &ShareVec) -> u64 {
        match &v.b {
            S2Custody::Remote { handle } => *handle,
            S2Custody::Local(_) => panic!(
                "locally-held shares met a remote center-b link — \
                 S2 custody must stay with center-b for the whole session"
            ),
        }
    }

    /// Concatenated S1 input bits for the GC inputs `parts`.
    fn garbler_bits_of(&self, parts: &[&ShareVec]) -> Vec<bool> {
        let mut ga = Vec::new();
        for part in parts {
            for &v in &part.a {
                ga.extend(self.bits_of_share(v));
            }
        }
        ga
    }

    /// Concatenated S2 input bits (local custody) for `parts`.
    fn evaluator_bits_of(&self, parts: &[&ShareVec]) -> Vec<bool> {
        let mut ea = Vec::new();
        for part in parts {
            for &v in self.local_b(part) {
                ea.extend(self.bits_of_share(v));
            }
        }
        ea
    }

    /// Open a trace span for one center-link phase. `tag` is the peer
    /// control tag the phase sends (exactly one frame per call), so the
    /// per-name round counter here and center-b's per-tag counter agree
    /// — the cross-process join key of the merged timeline.
    fn link_span(&mut self, name: &'static str, tag: u8) -> obs::Span {
        let ctr = self.span_rounds.entry(name).or_insert(0);
        let round = *ctr;
        *ctr += 1;
        obs::span(name).session(self.session).tag(tag).round(round)
    }

    /// Charge one link round-trip's stats and bytes to the ledger.
    fn charge_link(&mut self, stats: ExecStats, bytes0: u64, recv0: u64) {
        self.ledger.center_secs += stats.wall;
        self.ledger.gc_ands += stats.ands;
        self.ledger.ot_bits += stats.ot_bits;
        self.ledger.bytes += self.link.bytes_transferred() - bytes0;
        self.ledger.bytes_recv += self.link.bytes_received() - recv0;
        self.ledger.rounds += 2;
    }

    /// S2's input specification for a GC execution, matched to the link
    /// kind: literal bits in-process, stored-handle references remotely.
    fn eval_input(&self, parts: &[&ShareVec]) -> EvalInput {
        match &self.link {
            ShareLink::Local(_) => EvalInput::Bits(self.evaluator_bits_of(parts)),
            ShareLink::Peer(_) => {
                EvalInput::Handles(parts.iter().map(|p| self.remote_handle(p)).collect())
            }
        }
    }

    /// Run one *revealing* garbled program (Newton step, solve,
    /// convergence bit): S1 contributes `ga`; S2's inputs come from its
    /// own custody of `eval_parts` — bits fed directly in-process,
    /// handle references over the peer wire.
    fn run_gc(&mut self, spec: ProgSpec, ga: Vec<bool>, eval_parts: &[&ShareVec]) -> Vec<bool> {
        let mut sp =
            self.link_span("fabric.gc_exec", wire::TAG_GC_EXEC).u64("kind", spec.kind() as u64);
        let bytes0 = self.link.bytes_transferred();
        let recv0 = self.link.bytes_received();
        let fmt = self.fmt;
        let input = self.eval_input(eval_parts);
        let (out, stats) = match (&mut self.link, input) {
            (ShareLink::Local(session), EvalInput::Bits(ea)) => {
                execute_local(session, &spec, fmt, &ga, &ea)
            }
            (ShareLink::Peer(client), EvalInput::Handles(handles)) => {
                client.execute_reveal(&spec, fmt, &ga, &handles)
            }
            _ => unreachable!("eval_input always matches the link kind"),
        };
        if sp.active() {
            sp.record_u64("bytes", self.link.bytes_transferred() - bytes0);
            sp.record_u64("gc_ands", stats.ands);
            sp.record_u64("ot_bits", stats.ot_bits);
        }
        self.charge_link(stats, bytes0, recv0);
        out
    }

    /// The public key (nodes encrypt against it).
    pub fn public_key(&self) -> &crate::crypto::paillier::PublicKey {
        &self.kp.pk
    }

    /// The peer link's control-frame census (tag byte → count, both
    /// directions), when this fabric talks to a remote center-b. Tests
    /// use it to prove no S2 share material ever crossed: the only
    /// frame that can carry share values toward center-b is
    /// `ShareInput`, and it must never appear in a protocol run.
    pub fn peer_census(&self) -> Option<crate::mpc::peer::PeerCensus> {
        match &self.link {
            ShareLink::Peer(client) => Some(client.census()),
            ShareLink::Local(_) => None,
        }
    }
}

impl SecureFabric for RealFabric {
    fn fmt(&self) -> FixedFmt {
        self.fmt
    }

    fn packing(&self) -> Option<PackedCodec> {
        self.packing
    }

    fn node_encrypt_vec(&mut self, node: usize, vals: &[f64]) -> EncVec {
        let t0 = Instant::now();
        let ms: Vec<BigUint> = vals.iter().map(|&v| self.codec.encode(v)).collect();
        let cts =
            self.kp.pk.encrypt_batch(&ms, &mut ChaChaSource(&mut self.rng), pool::threads());
        self.ledger.paillier_encs += vals.len() as u64;
        let sent: u64 = cts.iter().map(|c| c.byte_len() as u64).sum();
        self.ledger.bytes += sent;
        self.ledger.bytes_recv += sent; // the Center receives what nodes send
        self.ledger.add_node(node, t0.elapsed().as_secs_f64());
        EncVec { scale: self.fmt.f, packed: None, data: EncData::Real(cts) }
    }

    fn node_apply_hinv(&mut self, node: usize, hinv: &EncMat, gj: &[f64]) -> EncVec {
        let t0 = Instant::now();
        let out = apply_hinv_real(self, hinv, gj);
        self.ledger.add_node(node, t0.elapsed().as_secs_f64());
        out
    }

    fn center_apply_hinv(&mut self, hinv: &EncMat, v: &[f64]) -> EncVec {
        let t0 = Instant::now();
        let out = apply_hinv_real(self, hinv, v);
        self.ledger.center_secs += t0.elapsed().as_secs_f64();
        out
    }

    fn aggregate(&mut self, parts: Vec<EncVec>) -> anyhow::Result<EncVec> {
        anyhow::ensure!(!parts.is_empty(), "aggregation needs at least one part");
        let mut sp = self
            .link_span("fabric.aggregate", wire::TAG_AGGREGATE)
            .u64("parts", parts.len() as u64);
        let t0 = Instant::now();
        let scale = parts[0].scale;
        let len = parts[0].len();
        let packed0 = parts[0].packed;
        // Node-reply shape is wire-controlled: validate as session
        // errors so one malformed node cannot panic the center.
        let mut cols: Vec<&[Ciphertext]> = Vec::with_capacity(parts.len());
        let mut total_parts: u128 = 0;
        for (j, part) in parts.iter().enumerate() {
            anyhow::ensure!(
                part.scale == scale,
                "aggregation scale mismatch: part {j} carries scale {}, part 0 carries {scale}",
                part.scale
            );
            match (packed0, part.packed) {
                (None, None) => {}
                (Some(m0), Some(m)) => {
                    anyhow::ensure!(
                        m.k == m0.k && m.slot_bits == m0.slot_bits && m.len == m0.len,
                        "aggregation packing mismatch: part {j} carries layout \
                         (k={}, b={}, len={}), part 0 carries (k={}, b={}, len={})",
                        m.k,
                        m.slot_bits,
                        m.len,
                        m0.k,
                        m0.slot_bits,
                        m0.len
                    );
                    total_parts = total_parts.saturating_add(m.parts);
                }
                _ => anyhow::bail!(
                    "aggregation packing mismatch: part {j} and part 0 disagree on \
                     whether the payload is slot-packed"
                ),
            }
            let cts = self.real_cts(part)?;
            anyhow::ensure!(
                cts.len() == len,
                "aggregation length mismatch: part {j} has {} ciphertexts, part 0 has {len}",
                cts.len()
            );
            cols.push(cts);
        }
        // A packed fold accumulates every part's biased contributions;
        // the sum must stay under the fan-in bound the slot width was
        // proven against, or slots could silently carry into neighbors.
        let packed = match packed0 {
            None => None,
            Some(m0) => {
                let codec = self.packing.ok_or_else(|| {
                    anyhow::anyhow!(
                        "packed node replies reached a center without a negotiated \
                         packing layout"
                    )
                })?;
                anyhow::ensure!(
                    m0.k == codec.k() && m0.slot_bits == codec.slot_bits(),
                    "packed node replies carry layout (k={}, b={}), session negotiated \
                     (k={}, b={})",
                    m0.k,
                    m0.slot_bits,
                    codec.k(),
                    codec.slot_bits()
                );
                anyhow::ensure!(
                    total_parts <= codec.max_parts() as u128,
                    "packing headroom term `fanin_sum` violated: folding {total_parts} \
                     contributions exceeds the negotiated bound {}",
                    codec.max_parts()
                );
                Some(PackedMeta { parts: total_parts, ..m0 })
            }
        };
        let bytes0 = self.link.bytes_transferred();
        let recv0 = self.link.bytes_received();
        let acc: Vec<Ciphertext> = match &mut self.link {
            // Per-element Montgomery-resident fold, fanned across
            // workers; wall time (not summed per-thread time) goes to
            // the ledger.
            ShareLink::Local(_) => {
                let pk = &self.kp.pk;
                pool::par_map_indexed(len, pool::threads(), |i| {
                    let column: Vec<&Ciphertext> = cols.iter().map(|cts| &cts[i]).collect();
                    pk.add_many(&column)
                })
            }
            // Split custody: relay the per-node vectors to center-b
            // without decrypting — S2 is the aggregator of Figure 1.
            // Center-b is mutually untrusting wire-controlled data too:
            // a malformed reply is a session error, not a center panic.
            ShareLink::Peer(client) => {
                let acc = client.aggregate(scale, &cols);
                anyhow::ensure!(
                    acc.len() == len,
                    "center-b answered Aggregate with {} ciphertexts, expected {len}",
                    acc.len()
                );
                acc
            }
        };
        self.ledger.paillier_adds += ((parts.len() - 1) * len) as u64;
        self.ledger.bytes += self.link.bytes_transferred() - bytes0;
        self.ledger.bytes_recv += self.link.bytes_received() - recv0;
        self.ledger.center_secs += t0.elapsed().as_secs_f64();
        self.ledger.rounds += 1;
        if sp.active() {
            sp.record_u64("len", len as u64);
            sp.record_u64("bytes", self.link.bytes_transferred() - bytes0);
        }
        Ok(EncVec { scale, packed, data: EncData::Real(acc) })
    }

    fn add_plain(&mut self, v: &EncVec, plain: &[f64]) -> anyhow::Result<EncVec> {
        let t0 = Instant::now();
        let cts = self.real_cts(v)?;
        let out: Vec<Ciphertext> = match v.packed {
            None => {
                anyhow::ensure!(
                    cts.len() == plain.len(),
                    "add_plain length mismatch: {} ciphertexts vs {} plaintexts",
                    cts.len(),
                    plain.len()
                );
                let mut out = Vec::with_capacity(cts.len());
                for (c, &pv) in cts.iter().zip(plain) {
                    let m = self.codec.encode_scaled(pv, v.scale)?;
                    out.push(self.kp.pk.add(c, &self.kp.pk.encrypt_trivial(&m)));
                }
                out
            }
            // Packed input: pack the plaintexts into the same slot
            // layout and fold them in as one more biased contribution.
            Some(meta) => {
                let codec = self.packing.ok_or_else(|| {
                    anyhow::anyhow!("packed add_plain without a negotiated packing layout")
                })?;
                anyhow::ensure!(
                    meta.k == codec.k() && meta.slot_bits == codec.slot_bits(),
                    "packed add_plain layout mismatch: vector carries (k={}, b={}), \
                     session negotiated (k={}, b={})",
                    meta.k,
                    meta.slot_bits,
                    codec.k(),
                    codec.slot_bits()
                );
                anyhow::ensure!(
                    plain.len() == meta.len,
                    "packed add_plain length mismatch: vector holds {} values, got {} \
                     plaintexts",
                    meta.len,
                    plain.len()
                );
                anyhow::ensure!(
                    meta.parts < codec.max_parts() as u128,
                    "packing headroom term `fanin_sum` violated: one more plain \
                     contribution on top of {} folded parts exceeds the negotiated \
                     bound {}",
                    meta.parts,
                    codec.max_parts()
                );
                let ms = codec.pack(plain, v.scale)?;
                cts.iter()
                    .zip(&ms)
                    .map(|(c, m)| self.kp.pk.add(c, &self.kp.pk.encrypt_trivial(m)))
                    .collect()
            }
        };
        self.ledger.paillier_adds += out.len() as u64;
        self.ledger.center_secs += t0.elapsed().as_secs_f64();
        Ok(EncVec {
            scale: v.scale,
            packed: v.packed.map(|m| PackedMeta { parts: m.parts + 1, ..m }),
            data: EncData::Real(out),
        })
    }

    fn to_shares(&mut self, v: &EncVec) -> anyhow::Result<SecVec> {
        anyhow::ensure!(
            v.scale == self.fmt.f,
            "to_shares expects scale-f ({}) values, got scale {}",
            self.fmt.f,
            v.scale
        );
        let mut sp =
            self.link_span("fabric.to_shares", wire::TAG_BLIND).u64("len", v.len() as u64);
        let t0 = Instant::now();
        let w = self.fmt.w;
        let mask_w = (1u128 << w) - 1;
        let cts = self.real_cts(v)?.to_vec();
        // Packed inputs: re-validate the metadata against the session
        // layout before any blind is drawn — the metadata traces back to
        // wire-controlled node replies.
        let packed = match v.packed {
            None => None,
            Some(meta) => {
                let codec = self.packing.ok_or_else(|| {
                    anyhow::anyhow!(
                        "packed to_shares without a negotiated packing layout"
                    )
                })?;
                anyhow::ensure!(
                    meta.k == codec.k() && meta.slot_bits == codec.slot_bits(),
                    "packed to_shares layout mismatch: vector carries (k={}, b={}), \
                     session negotiated (k={}, b={})",
                    meta.k,
                    meta.slot_bits,
                    codec.k(),
                    codec.slot_bits()
                );
                anyhow::ensure!(
                    meta.parts >= 1 && meta.parts <= codec.max_parts() as u128,
                    "packing headroom term `fanin_sum` violated: payload claims \
                     {} contributions, negotiated bound is {}",
                    meta.parts,
                    codec.max_parts()
                );
                anyhow::ensure!(
                    cts.len() == codec.cts_needed(meta.len),
                    "packed payload of {} values needs {} ciphertexts, got {}",
                    meta.len,
                    codec.cts_needed(meta.len),
                    cts.len()
                );
                Some((codec, meta))
            }
        };
        let handle = self.next_handle;
        let link_bytes0 = self.link.bytes_transferred();
        let shares = match (&mut self.link, packed) {
            (ShareLink::Local(_), None) => {
                let lift = BigUint::one().shl(w - 1); // C = 2^{w-1}
                let mask_bound = BigUint::one().shl(w + SIGMA);
                // S2's blinds are drawn serially (fixed RNG stream); the
                // blind-encrypt-decrypt pipeline then fans out per element.
                let rhos: Vec<BigUint> =
                    cts.iter().map(|_| self.rng.below(&mask_bound)).collect();
                let pk = &self.kp.pk;
                let sk = &self.kp.sk;
                let lift_ref = &lift;
                let blinded: Vec<((u128, u128), u64)> =
                    pool::par_map_indexed(cts.len(), pool::threads(), |i| {
                        // S2: blind with C + ρ.
                        let blind = lift_ref.add(&rhos[i]);
                        let blinded = pk.add(&cts[i], &pk.encrypt_trivial(&blind));
                        // S1: decrypt y = x + C + ρ (no wrap: |x| < 2^{w-1} ≪ n).
                        let y = sk.decrypt(&blinded);
                        let a = u128_of(&y) & mask_w;
                        let b = blind_b_half(&blind, w);
                        ((a, b), blinded.byte_len() as u64)
                    });
                let mut a = Vec::with_capacity(cts.len());
                let mut b = Vec::with_capacity(cts.len());
                for ((ai, bi), ct_bytes) in blinded {
                    self.ledger.bytes += ct_bytes;
                    self.ledger.bytes_recv += ct_bytes; // S1 receives the blinded ct
                    a.push(ai);
                    b.push(bi);
                }
                ShareVec { a, b: S2Custody::Local(b) }
            }
            // Packed in-process conversion: one blind ρ per *slot*, laid
            // out in the same radix-2^b positions as the values, so one
            // homomorphic add masks a whole ciphertext. No lift term —
            // the biased slots already carry `parts·B`, which plays the
            // unpacked conversion's `C = 2^{w-1}` role.
            (ShareLink::Local(_), Some((codec, meta))) => {
                let (rhos, b) = packed_blinds(&mut self.rng, w, meta.parts, meta.len);
                let slot_b = codec.slot_bits() as usize;
                let k = codec.k() as usize;
                let pk = &self.kp.pk;
                let sk = &self.kp.sk;
                let rhos_ref = &rhos;
                let decoded: Vec<(Vec<u128>, u64)> =
                    pool::par_map_indexed(cts.len(), pool::threads(), |ci| {
                        let lo = ci * k;
                        let hi = lo + codec.slots_in_ct(meta.len, ci);
                        let mut mask = BigUint::zero();
                        for i in (lo..hi).rev() {
                            mask = mask.shl(slot_b).add(&rhos_ref[i]);
                        }
                        let blinded = pk.add(&cts[ci], &pk.encrypt_trivial(&mask));
                        // S1: decrypt, then read each slot's y_i =
                        // x_i + parts·B + ρ_i (headroom: no slot carry).
                        let y = sk.decrypt(&blinded);
                        let a: Vec<u128> = (lo..hi)
                            .map(|i| u128_of(&codec.slot(&y, i - lo)) & mask_w)
                            .collect();
                        (a, blinded.byte_len() as u64)
                    });
                let mut a = Vec::with_capacity(meta.len);
                for (ai, ct_bytes) in decoded {
                    self.ledger.bytes += ct_bytes;
                    self.ledger.bytes_recv += ct_bytes; // S1 receives the blinded ct
                    a.extend(ai);
                }
                ShareVec { a, b: S2Custody::Local(b) }
            }
            (ShareLink::Peer(client), packed) => {
                self.next_handle += 1;
                let bytes0 = client.bytes_sent() + client.bytes_received();
                // S2 draws the blinds ρ itself, keeps its halves under
                // `handle`, and only the blinded ciphertexts come back.
                // For packed inputs the Blind frame is self-describing:
                // center-b re-validates the layout and draws one blind
                // per slot.
                let blinded = client.blind(handle, &cts, v.packed);
                anyhow::ensure!(
                    blinded.len() == cts.len(),
                    "center-b answered Blind with {} ciphertexts, expected {}",
                    blinded.len(),
                    cts.len()
                );
                let sk = &self.kp.sk;
                let a: Vec<u128> = match packed {
                    None => pool::par_map_indexed(blinded.len(), pool::threads(), |i| {
                        u128_of(&sk.decrypt(&blinded[i])) & mask_w
                    }),
                    Some((codec, meta)) => {
                        let per_ct: Vec<Vec<u128>> =
                            pool::par_map_indexed(blinded.len(), pool::threads(), |ci| {
                                let y = sk.decrypt(&blinded[ci]);
                                (0..codec.slots_in_ct(meta.len, ci))
                                    .map(|s| u128_of(&codec.slot(&y, s)) & mask_w)
                                    .collect()
                            });
                        per_ct.into_iter().flatten().collect()
                    }
                };
                let delta = client.bytes_sent() + client.bytes_received() - bytes0;
                self.ledger.bytes += delta;
                self.ledger.bytes_recv += delta;
                ShareVec { a, b: S2Custody::Remote { handle } }
            }
        };
        self.ledger.paillier_adds += cts.len() as u64;
        self.ledger.paillier_decrypts += cts.len() as u64;
        self.ledger.rounds += 2;
        self.ledger.center_secs += t0.elapsed().as_secs_f64();
        if sp.active() {
            sp.record_u64("bytes", self.link.bytes_transferred() - link_bytes0);
        }
        Ok(SecVec::Shares(shares))
    }

    fn decrypt_reveal(&mut self, v: &EncVec) -> Vec<f64> {
        let ctr = self.span_rounds.entry("fabric.reveal").or_insert(0);
        let round = *ctr;
        *ctr += 1;
        let _sp = obs::span("fabric.reveal")
            .session(self.session)
            .round(round)
            .u64("len", v.len() as u64);
        let t0 = Instant::now();
        let cts = self.expect_real(v);
        let sk = &self.kp.sk;
        let codec = &self.codec;
        let out: Vec<f64> = match v.packed {
            None => pool::par_map_indexed(cts.len(), pool::threads(), |i| {
                codec.decode_scaled(&sk.decrypt(&cts[i]), v.scale)
            }),
            // Packed reveal: decrypt, then unpack every slot. The
            // metadata was validated when the vector was aggregated, so
            // a failure here means a fabric-internal invariant broke —
            // same contract as the expect_real shape panics above.
            Some(meta) => {
                let packing = self
                    .packing
                    .expect("packed vector revealed on a fabric without a packing layout");
                let ms: Vec<BigUint> =
                    pool::par_map_indexed(cts.len(), pool::threads(), |i| sk.decrypt(&cts[i]));
                packing
                    .unpack_vec(&ms, meta.len, meta.parts, v.scale)
                    .unwrap_or_else(|e| panic!("packed reveal: {e}"))
            }
        };
        self.ledger.paillier_decrypts += cts.len() as u64;
        let sent: u64 = cts.iter().map(|c| c.byte_len() as u64).sum();
        self.ledger.bytes += sent;
        self.ledger.bytes_recv += sent; // S1 receives the reveal requests
        self.ledger.rounds += 2;
        self.ledger.center_secs += t0.elapsed().as_secs_f64();
        out
    }

    fn newton_step(&mut self, h_tri: &SecVec, g: &SecVec, p: usize) -> Vec<f64> {
        let h = self.expect_shares(h_tri);
        let gv = self.expect_shares(g);
        let ga = self.garbler_bits_of(&[h, gv]);
        let out = self.run_gc(ProgSpec::Newton { p }, ga, &[h, gv]);
        self.decode_out_words(&out)
    }

    fn cholesky_shares(&mut self, h_tri: &SecVec, p: usize) -> SecVec {
        let h = self.expect_shares(h_tri);
        let nh = tri_len(p);
        let w = self.fmt.w;
        let fmt = self.fmt;
        let mask_w = (1u128 << w) - 1;
        let masks: Vec<u128> = (0..nh)
            .map(|_| ((self.rng.next_u64() as u128) << 64 | self.rng.next_u64() as u128) & mask_w)
            .collect();
        let mut ga = self.garbler_bits_of(&[h]);
        for &m in &masks {
            ga.extend(self.bits_of_share(m));
        }
        // S1's output shares come from its own masks; S2's are the
        // masked program outputs — held locally in-process, stored at
        // center-b under a fresh handle over the peer link.
        let a_out: Vec<u128> =
            masks.iter().map(|&m| (1u128 << w).wrapping_sub(m) & mask_w).collect();
        let out_handle = self.next_handle;
        let mut sp = self
            .link_span("fabric.gc_exec", wire::TAG_GC_EXEC)
            .u64("kind", ProgSpec::CholeskyShare { p }.kind() as u64);
        let bytes0 = self.link.bytes_transferred();
        let recv0 = self.link.bytes_received();
        let input = self.eval_input(&[h]);
        let (bvals, stats) = match (&mut self.link, input) {
            (ShareLink::Local(session), EvalInput::Bits(ea)) => {
                let (out, stats) =
                    execute_local(session, &ProgSpec::CholeskyShare { p }, fmt, &ga, &ea);
                (Some(words_of_bits(&out, w)), stats)
            }
            (ShareLink::Peer(client), EvalInput::Handles(handles)) => {
                self.next_handle += 1;
                let stats = client.execute_to_share(
                    &ProgSpec::CholeskyShare { p },
                    fmt,
                    &ga,
                    &handles,
                    out_handle,
                );
                (None, stats)
            }
            _ => unreachable!("eval_input always matches the link kind"),
        };
        if sp.active() {
            sp.record_u64("bytes", self.link.bytes_transferred() - bytes0);
            sp.record_u64("gc_ands", stats.ands);
        }
        self.charge_link(stats, bytes0, recv0);
        let b = match bvals {
            Some(b) => S2Custody::Local(b),
            None => S2Custody::Remote { handle: out_handle },
        };
        SecVec::Shares(ShareVec { a: a_out, b })
    }

    fn solve_reveal(&mut self, l_tri: &SecVec, g: &SecVec, p: usize) -> Vec<f64> {
        let l = self.expect_shares(l_tri);
        let gv = self.expect_shares(g);
        let ga = self.garbler_bits_of(&[l, gv]);
        let out = self.run_gc(ProgSpec::Solve { p }, ga, &[l, gv]);
        self.decode_out_words(&out)
    }

    fn inverse_to_enc(&mut self, h_tri: &SecVec, p: usize) -> EncMat {
        let fmt = self.fmt;
        let wide = InverseMaskedProg { p, fmt }.wide();
        let h = self.expect_shares(h_tri);
        let nh = tri_len(p);
        let w = self.fmt.w;
        // garbler masks r_i: (w+σ)-bit
        let masks: Vec<u128> = (0..nh)
            .map(|_| {
                ((self.rng.next_u64() as u128) << 64 | self.rng.next_u64() as u128)
                    & ((1u128 << (w + SIGMA)) - 1)
            })
            .collect();
        let mut ga = self.garbler_bits_of(&[h]);
        for &m in &masks {
            ga.extend((0..w + SIGMA).map(|i| (m >> i) & 1 == 1));
        }
        let lift = BigUint::one().shl(w - 1);
        let mut sp = self
            .link_span("fabric.gc_exec", wire::TAG_GC_EXEC)
            .u64("kind", ProgSpec::InverseMasked { p }.kind() as u64);
        let bytes0 = self.link.bytes_transferred();
        let recv0 = self.link.bytes_received();
        let input = self.eval_input(&[h]);
        let (outcome, stats) = match (&mut self.link, input) {
            (ShareLink::Local(session), EvalInput::Bits(ea)) => {
                let (out, stats) =
                    execute_local(session, &ProgSpec::InverseMasked { p }, fmt, &ga, &ea);
                (InverseOutcome::Bits(out), stats)
            }
            (ShareLink::Peer(client), EvalInput::Handles(handles)) => {
                // S1's corrections Enc(C + r_i) travel to center-b, so
                // they must be *randomized* encryptions — a trivial
                // encryption would hand S2 the masks r and with them the
                // unmasked H̃⁻¹ entries.
                let crs: Vec<BigUint> =
                    masks.iter().map(|&m| lift.add(&BigUint::from_u128(m))).collect();
                let corrections = self.kp.pk.encrypt_batch(
                    &crs,
                    &mut ChaChaSource(&mut self.rng),
                    pool::threads(),
                );
                let (cts, stats) = client.execute_encrypt(
                    &ProgSpec::InverseMasked { p },
                    fmt,
                    &ga,
                    &handles,
                    &corrections,
                );
                (InverseOutcome::Cts(cts), stats)
            }
            _ => unreachable!("eval_input always matches the link kind"),
        };
        if sp.active() {
            sp.record_u64("bytes", self.link.bytes_transferred() - bytes0);
            sp.record_u64("gc_ands", stats.ands);
        }
        sp.done();
        self.charge_link(stats, bytes0, recv0);
        let t0 = Instant::now();
        let cts: Vec<Ciphertext> = match outcome {
            // In-process: this side also plays S2 — assemble the wide
            // masked integers, encrypt, subtract Enc(C + r).
            InverseOutcome::Bits(out) => {
                let ys: Vec<BigUint> =
                    words_of_bits(&out, wide).into_iter().map(BigUint::from_u128).collect();
                // S2 encrypts the masked values as one parallel batch
                // (the RNG stream matches sequential encryption), then
                // S1's Enc(C + r) correction is subtracted per element —
                // trivial encryption suffices in-process; hiding comes
                // from enc_y's randomness.
                let enc_ys = self.kp.pk.encrypt_batch(
                    &ys,
                    &mut ChaChaSource(&mut self.rng),
                    pool::threads(),
                );
                let pk = &self.kp.pk;
                pool::par_map_indexed(enc_ys.len(), pool::threads(), |i| {
                    let cr = lift.add(&BigUint::from_u128(masks[i]));
                    pk.sub(&enc_ys[i], &pk.encrypt_trivial(&cr))
                })
            }
            // Split custody: center-b already encrypted its wide outputs
            // and subtracted S1's randomized corrections itself. A short
            // reply aborts loudly (the GC path's contract; the center
            // CLIs convert the unwind into a clean error exit).
            InverseOutcome::Cts(cts) => {
                assert_eq!(
                    cts.len(),
                    nh,
                    "center-b answered the masked inverse with a wrong-length ciphertext vector"
                );
                self.ledger.paillier_encs += nh as u64; // S1's corrections
                cts
            }
        };
        self.ledger.paillier_encs += nh as u64;
        self.ledger.paillier_adds += nh as u64;
        let sent: u64 = cts.iter().map(|c| c.byte_len() as u64).sum();
        self.ledger.bytes += sent;
        self.ledger.bytes_recv += sent; // nodes receive the broadcast Enc(H̃⁻¹)
        self.ledger.rounds += 2;
        self.ledger.center_secs += t0.elapsed().as_secs_f64();
        EncMat { p, tri: EncVec { scale: self.fmt.f, packed: None, data: EncData::Real(cts) } }
    }

    fn converged(&mut self, l_new: &SecVec, l_old: &SecVec, tol: f64) -> bool {
        let ln = self.expect_shares(l_new);
        let lo = self.expect_shares(l_old);
        // The convergence check compares two aggregated scalars; handles
        // are whole-vector references, so the inputs must be 1-element.
        assert_eq!(ln.len(), 1, "converged expects a 1-element share vector");
        assert_eq!(lo.len(), 1, "converged expects a 1-element share vector");
        let ga = self.garbler_bits_of(&[ln, lo]);
        let out = self.run_gc(ProgSpec::Converged { tol }, ga, &[ln, lo]);
        out[0]
    }

    fn ledger(&self) -> &CostLedger {
        &self.ledger
    }
    fn ledger_mut(&mut self) -> &mut CostLedger {
        &mut self.ledger
    }
    fn cost_model(&self) -> &CostModel {
        &self.net
    }
    fn backend_label(&self) -> &'static str {
        self.label
    }
    fn session_id(&self) -> u64 {
        self.session
    }
    fn peer_tag_flows(&self) -> std::collections::BTreeMap<u8, crate::obs::TagFlow> {
        match &self.link {
            ShareLink::Peer(client) => client.tag_flows(),
            ShareLink::Local(_) => std::collections::BTreeMap::new(),
        }
    }
}

/// `Enc(H̃⁻¹)` prepared for repeated weighted-row application: every
/// packed-triangle ciphertext resident in Montgomery form with its
/// Straus window table built once, plus lazily-built inverse-base tables
/// for negative coefficients (one extended-gcd inverse per triangle
/// entry *ever*, versus one per row×column occurrence for the naive
/// loop). [`crate::net::NodeServer`] builds this once per `SetHinv`
/// broadcast and reuses it across every `StepReq` round.
pub struct PreparedHinv {
    p: usize,
    mont: Arc<Montgomery>,
    n2: BigUint,
    pos: Vec<StrausTable>,
    neg: Vec<OnceLock<StrausTable>>,
}

impl PreparedHinv {
    /// Enter the triangle into Montgomery form and build the per-entry
    /// Straus tables (fanned across `workers` threads).
    ///
    /// Contract: every triangle entry must be a unit of `Z_{n²}` (all
    /// honestly-constructed ciphertexts are); a non-invertible entry
    /// panics later, inside [`PreparedHinv::apply`], when a negative
    /// coefficient first needs its inverse table. Wire-facing callers
    /// validate before preparing (see `net::server`'s `SetHinv`).
    pub fn prepare(pk: &PublicKey, p: usize, tri: &[Ciphertext], workers: usize) -> PreparedHinv {
        assert_eq!(tri.len(), tri_len(p));
        let mont = pk.n2_mont();
        let mref = &mont;
        let pos: Vec<StrausTable> = pool::par_map_indexed(tri.len(), workers, |i| {
            mref.straus_table(&mref.enter(&tri[i].0))
        });
        let neg = (0..tri.len()).map(|_| OnceLock::new()).collect();
        PreparedHinv { p, mont, n2: pk.n2.clone(), pos, neg }
    }

    /// Dimensionality of the prepared triangle.
    pub fn p(&self) -> usize {
        self.p
    }

    fn table(&self, idx: usize, positive: bool) -> &StrausTable {
        if positive {
            &self.pos[idx]
        } else {
            self.neg[idx].get_or_init(|| {
                let b = self.mont.exit(self.pos[idx].base());
                let inv = b.modinv(&self.n2).expect("ciphertext invertible mod n²");
                self.mont.straus_table(&self.mont.enter(&inv))
            })
        }
    }

    /// `Enc(H̃⁻¹) ⊗ v`: each output row `i` is the single simultaneous
    /// multi-exponentiation `∏_j tri[idx(i,j)]^{k_j}` with the small
    /// signed constants `k_j = encode(v_j)` — one shared squaring chain
    /// per row instead of one per term. Rows fan out across `workers`
    /// threads; the result is bit-identical to
    /// [`apply_hinv_cts_reference`] for any worker count.
    ///
    /// Returns the `p` row ciphertexts plus the scalar-op and
    /// homomorphic-addition counts for ledger attribution (computed from
    /// the coefficient structure, so they match the reference loop
    /// exactly and never depend on scheduling).
    pub fn apply(&self, fmt: FixedFmt, v: &[f64], workers: usize) -> (Vec<Ciphertext>, u64, u64) {
        let p = self.p;
        assert_eq!(v.len(), p);
        let k: Vec<i128> = v.iter().map(|&x| fmt.encode(x)).collect();
        let nnz = k.iter().filter(|&&x| x != 0).count() as u64;
        let scalar_ops = p as u64 * nnz;
        let adds = p as u64 * nnz.saturating_sub(1);
        let kref = &k;
        let rows: Vec<Ciphertext> = pool::par_map_indexed(p, workers, |i| {
            let mut terms: Vec<(&StrausTable, u128)> = Vec::with_capacity(p);
            for (j, &kj) in kref.iter().enumerate() {
                if kj == 0 {
                    continue;
                }
                let idx = if i >= j { tri_idx(i, j) } else { tri_idx(j, i) };
                terms.push((self.table(idx, kj > 0), kj.unsigned_abs()));
            }
            Ciphertext(self.mont.exit(&self.mont.multi_pow(&terms)))
        });
        (rows, scalar_ops, adds)
    }
}

/// `Enc(H̃⁻¹) ⊗ v` over raw ciphertexts: multiply-by-(small signed)
/// constant rows — the cheap primitive PrivLogit-Local is built on.
/// Shared by the center-side fabric and [`crate::net::NodeServer`]
/// (Alg. 3 step 7). One-shot convenience over [`PreparedHinv`]; callers
/// that apply the same triangle repeatedly should prepare once.
///
/// Returns the `p` row ciphertexts (scale `2f`) plus the scalar-op and
/// homomorphic-addition counts for ledger attribution.
pub fn apply_hinv_cts(
    pk: &PublicKey,
    fmt: FixedFmt,
    p: usize,
    tri: &[Ciphertext],
    v: &[f64],
) -> (Vec<Ciphertext>, u64, u64) {
    let workers = pool::threads();
    PreparedHinv::prepare(pk, p, tri, workers).apply(fmt, v, workers)
}

/// Reference `Enc(H̃⁻¹) ⊗ v`: the naive per-term loop (one full windowed
/// `pow` per nonzero coefficient, one `⊕` per accumulation) this module
/// replaced with Straus multi-exponentiation. Kept callable for parity
/// property tests and the micro-bench speedup comparison.
pub fn apply_hinv_cts_reference(
    pk: &PublicKey,
    fmt: FixedFmt,
    p: usize,
    tri: &[Ciphertext],
    v: &[f64],
) -> (Vec<Ciphertext>, u64, u64) {
    assert_eq!(v.len(), p);
    assert_eq!(tri.len(), tri_len(p));
    let mut rows: Vec<Option<Ciphertext>> = vec![None; p];
    let mut scalar_ops = 0u64;
    let mut adds = 0u64;
    for i in 0..p {
        for j in 0..p {
            let idx = if i >= j { tri_idx(i, j) } else { tri_idx(j, i) };
            let raw = fmt.encode(v[j]); // small signed constant (≤ w bits)
            if raw == 0 {
                continue;
            }
            let term = scalar_mul_signed(pk, &tri[idx], raw);
            scalar_ops += 1;
            rows[i] = Some(match rows[i].take() {
                None => term,
                Some(acc) => {
                    adds += 1;
                    pk.add(&acc, &term)
                }
            });
        }
    }
    let zero = pk.encrypt_trivial(&BigUint::zero());
    let cts: Vec<Ciphertext> =
        rows.into_iter().map(|r| r.unwrap_or_else(|| zero.clone())).collect();
    (cts, scalar_ops, adds)
}

/// Fabric-side wrapper over [`PreparedHinv`] (node or center time
/// attribution is handled by the caller). The prepared triangle is
/// cached on the fabric and rebuilt only when the broadcast changes.
fn apply_hinv_real(fab: &mut RealFabric, hinv: &EncMat, v: &[f64]) -> EncVec {
    let tri = match &hinv.tri.data {
        EncData::Real(c) => c,
        _ => panic!("model EncMat in RealFabric"),
    };
    let fmt = fab.fmt;
    let workers = pool::threads();
    let cache_hit = matches!(&fab.prepared_hinv, Some((key, _)) if key.as_slice() == &tri[..]);
    if !cache_hit {
        let prepared = PreparedHinv::prepare(&fab.kp.pk, hinv.p, tri, workers);
        fab.prepared_hinv = Some((tri.clone(), prepared));
    }
    let (_, prepared) = fab.prepared_hinv.as_ref().expect("cached above");
    let (cts, scalar_ops, adds) = prepared.apply(fmt, v, workers);
    fab.ledger.paillier_scalar += scalar_ops;
    fab.ledger.paillier_adds += adds;
    let sent: u64 = cts.iter().map(|c| c.byte_len() as u64).sum();
    fab.ledger.bytes += sent;
    fab.ledger.bytes_recv += sent; // the aggregating Center receives the partials
    EncVec { scale: 2 * fmt.f, packed: None, data: EncData::Real(cts) }
}

/// `ct^k` for a *signed* small constant `k`: negative constants go through
/// the ciphertext inverse so the exponent stays small (this is what keeps
/// PL-Local's multiply-by-constant cheap; a naive `n−|k|` exponent would
/// be modulus-sized).
fn scalar_mul_signed(
    pk: &crate::crypto::paillier::PublicKey,
    ct: &Ciphertext,
    k: i128,
) -> Ciphertext {
    let mag = BigUint::from_u128(k.unsigned_abs());
    if k >= 0 {
        pk.scalar_mul(ct, &mag)
    } else {
        let inv = ct.0.modinv(&pk.n2).expect("ciphertext invertible");
        pk.scalar_mul(&Ciphertext(inv), &mag)
    }
}

/// Assemble little-endian bit chunks of width `chunk` into words. The
/// fabric's in-process S2 arms and the center-b peer server must pack
/// output bits into share words identically, or shares would not
/// recombine across deployments — one implementation, shared.
pub(crate) fn words_of_bits(bits: &[bool], chunk: usize) -> Vec<u128> {
    bits.chunks(chunk)
        .map(|c| {
            let mut v: u128 = 0;
            for (i, &bit) in c.iter().enumerate() {
                if bit {
                    v |= 1 << i;
                }
            }
            v
        })
        .collect()
}

/// Serially-drawn packed-conversion blinds: one ρ per slot below
/// `2^(w + ⌈log₂(parts+1)⌉ + σ)`, plus S2's share half for the slot's
/// total blind `parts·B + ρ` — the biased slots already carry
/// `parts·B = parts·2^{w−1}`, which plays the unpacked conversion's
/// lift role, so no extra lift is added. The `blind_mask` headroom term
/// guarantees slot + blind stays under `2^b` (no slot carry). The
/// fabric's in-process arm and the center-b peer server must draw and
/// derive these identically — one implementation, shared.
pub(crate) fn packed_blinds(
    rng: &mut ChaChaRng,
    w: usize,
    parts: u128,
    count: usize,
) -> (Vec<BigUint>, Vec<u128>) {
    let parts_bits = (128 - parts.leading_zeros()) as usize;
    let bound = BigUint::one().shl(w + parts_bits + BLIND_SIGMA as usize);
    let bias_total = BigUint::from_u128(parts).shl(w - 1);
    let mut rhos = Vec::with_capacity(count);
    let mut halves = Vec::with_capacity(count);
    for _ in 0..count {
        let rho = rng.below(&bound);
        halves.push(blind_b_half(&bias_total.add(&rho), w));
        rhos.push(rho);
    }
    (rhos, halves)
}

/// S2's share half for a blind `C + ρ`: `b = 2^w − ((C + ρ) mod 2^w)`.
/// The fabric's in-process arm and the center-b peer server must derive
/// the half identically, or in-process and split-process shares would
/// recombine differently — one implementation, shared.
pub(crate) fn blind_b_half(blind: &BigUint, w: usize) -> u128 {
    let mask_w = (1u128 << w) - 1;
    (1u128 << w).wrapping_sub(u128_of(blind) & mask_w) & mask_w
}

/// Low 128 bits of a little-endian bigint (share-word extraction;
/// shared with the center-b peer server).
pub(crate) fn u128_of(v: &BigUint) -> u128 {
    let bytes = v.to_bytes_le();
    let mut buf = [0u8; 16];
    let n = bytes.len().min(16);
    buf[..n].copy_from_slice(&bytes[..n]);
    u128::from_le_bytes(buf)
}

// ======================================================================
// Modeled backend
// ======================================================================

/// Cost-model backend: plaintext numerics on the fixed-point grid plus a
/// virtual clock (see module docs).
pub struct ModelFabric {
    fmt: FixedFmt,
    ledger: CostLedger,
    cost: CostModel,
    /// Modeled Paillier modulus size (bytes accounting only).
    ct_bytes: u64,
    gate_cache: HashMap<ProgKind, (u64, u64)>,
}

impl ModelFabric {
    /// New modeled fabric; `modulus_bits` only affects byte accounting.
    pub fn new(modulus_bits: usize, fmt: FixedFmt) -> Self {
        ModelFabric {
            fmt,
            ledger: CostLedger::default(),
            cost: CostModel::load(CostModel::CALIBRATION_PATH),
            ct_bytes: (2 * modulus_bits / 8) as u64,
            gate_cache: HashMap::new(),
        }
    }

    fn quant(&self, v: f64) -> f64 {
        self.fmt.decode(self.fmt.encode(v))
    }

    fn expect_model<'a>(&self, v: &'a EncVec) -> &'a [f64] {
        match &v.data {
            EncData::Model(m) => m,
            EncData::Real(_) => panic!("real EncVec passed to ModelFabric"),
        }
    }

    fn expect_model_sec<'a>(&self, v: &'a SecVec) -> &'a [f64] {
        match v {
            SecVec::Model(m) => m,
            SecVec::Shares(_) => panic!("real SecVec passed to ModelFabric"),
        }
    }

    /// Exact gate/OT counts for a program (cached; data-independent).
    ///
    /// Program gate counts are exact cubic polynomials in `p` (every word
    /// op has a fixed gate cost and the op counts are cubic), so beyond
    /// `INTERP_LIMIT` we interpolate from four exact evaluations instead
    /// of walking ~10¹¹ gates for a p=400 circuit. Lagrange on integer
    /// nodes is exact in f64 well past these magnitudes.
    fn gc_cost(&mut self, kind: ProgKind) -> (u64, u64) {
        const INTERP_LIMIT: usize = 24;
        const NODES: [usize; 4] = [6, 12, 18, 24];
        if let Some(&c) = self.gate_cache.get(&kind) {
            return c;
        }
        let (p_opt, rebuild): (Option<usize>, fn(usize) -> ProgKind) = match kind {
            ProgKind::Newton(p) => (Some(p), ProgKind::Newton),
            ProgKind::Cholesky(p) => (Some(p), ProgKind::Cholesky),
            ProgKind::Solve(p) => (Some(p), ProgKind::Solve),
            ProgKind::Inverse(p) => (Some(p), ProgKind::Inverse),
            ProgKind::Converged => (None, |_| ProgKind::Converged),
        };
        let result = match p_opt {
            Some(p) if p > INTERP_LIMIT => {
                let samples: Vec<(f64, f64, f64)> = NODES
                    .iter()
                    .map(|&q| {
                        let (a, o) = self.gc_cost(rebuild(q));
                        (q as f64, a as f64, o as f64)
                    })
                    .collect();
                let lagrange = |pick: fn(&(f64, f64, f64)) -> f64| -> u64 {
                    let x = p as f64;
                    let mut acc = 0.0;
                    for (i, si) in samples.iter().enumerate() {
                        let mut term = pick(si);
                        for (j, sj) in samples.iter().enumerate() {
                            if i != j {
                                term *= (x - sj.0) / (si.0 - sj.0);
                            }
                        }
                        acc += term;
                    }
                    acc.round().max(0.0) as u64
                };
                (lagrange(|s| s.1), lagrange(|s| s.2))
            }
            _ => {
                let fmt = self.fmt;
                match kind {
                    ProgKind::Newton(p) => count_prog(&NewtonStepProg { p, fmt }),
                    ProgKind::Cholesky(p) => count_prog(&CholeskyShareProg { p, fmt }),
                    ProgKind::Solve(p) => count_prog(&SolveProg { p, fmt }),
                    ProgKind::Inverse(p) => count_prog(&InverseMaskedProg { p, fmt }),
                    ProgKind::Converged => count_prog(&ConvergedProg { fmt, tol: 1e-6 }),
                }
            }
        };
        self.gate_cache.insert(kind, result);
        result
    }

    fn charge_gc(&mut self, kind: ProgKind) {
        let (ands, otbits) = self.gc_cost(kind);
        self.ledger.center_secs += ands as f64 * self.cost.t_and + otbits as f64 * self.cost.t_ot;
        self.ledger.gc_ands += ands;
        self.ledger.ot_bits += otbits;
        // 32 bytes/AND (two half-gate rows) + 16 bytes per input label.
        self.ledger.bytes += ands * 32 + otbits * 16;
        self.ledger.bytes_recv += ands * 32 + otbits * 16;
        self.ledger.rounds += 2;
    }
}

fn count_prog<P: GcProgram>(prog: &P) -> (u64, u64) {
    let mut cb = CountBackend::default();
    let ga = vec![None; prog.inputs_garbler()];
    let ea = vec![None; prog.inputs_evaluator()];
    prog.run(&mut cb, &ga, &ea);
    (cb.ands, prog.inputs_evaluator() as u64)
}

impl SecureFabric for ModelFabric {
    fn fmt(&self) -> FixedFmt {
        self.fmt
    }

    fn node_encrypt_vec(&mut self, node: usize, vals: &[f64]) -> EncVec {
        let vq: Vec<f64> = vals.iter().map(|&v| self.quant(v)).collect();
        self.ledger.paillier_encs += vals.len() as u64;
        self.ledger.bytes += vals.len() as u64 * self.ct_bytes;
        self.ledger.bytes_recv += vals.len() as u64 * self.ct_bytes;
        self.ledger.add_node(node, vals.len() as f64 * self.cost.t_enc);
        EncVec { scale: self.fmt.f, packed: None, data: EncData::Model(vq) }
    }

    fn node_apply_hinv(&mut self, node: usize, hinv: &EncMat, gj: &[f64]) -> EncVec {
        let p = hinv.p;
        // Modeled as the real backend's Straus multi-exp row primitive:
        // p² row terms at the amortized per-term cost (squarings and
        // additions included), single-threaded — conservative versus
        // the parallel node servers. Op *counts* below keep the
        // homomorphic-operation semantics for cross-backend tables.
        let secs = (p * p) as f64 * self.cost.t_apply_term;
        self.ledger.add_node(node, secs);
        self.ledger.paillier_scalar += (p * p) as u64;
        self.ledger.paillier_adds += (p * (p - 1)) as u64;
        self.ledger.bytes += p as u64 * self.ct_bytes;
        self.ledger.bytes_recv += p as u64 * self.ct_bytes;
        apply_hinv_model(self, hinv, gj)
    }

    fn center_apply_hinv(&mut self, hinv: &EncMat, v: &[f64]) -> EncVec {
        let p = hinv.p;
        self.ledger.center_secs += (p * p) as f64 * self.cost.t_apply_term;
        self.ledger.paillier_scalar += (p * p) as u64;
        self.ledger.paillier_adds += (p * (p - 1)) as u64;
        apply_hinv_model(self, hinv, v)
    }

    fn aggregate(&mut self, parts: Vec<EncVec>) -> anyhow::Result<EncVec> {
        anyhow::ensure!(!parts.is_empty(), "aggregation needs at least one part");
        let scale = parts[0].scale;
        let len = parts[0].len();
        let mut acc = vec![0.0; len];
        for (j, part) in parts.iter().enumerate() {
            anyhow::ensure!(
                part.scale == scale,
                "aggregation scale mismatch: part {j} carries scale {}, part 0 carries {scale}",
                part.scale
            );
            anyhow::ensure!(
                part.len() == len,
                "aggregation length mismatch: part {j} has {} values, part 0 has {len}",
                part.len()
            );
            for (a, v) in acc.iter_mut().zip(self.expect_model(part)) {
                *a += v;
            }
        }
        self.ledger.paillier_adds += ((parts.len() - 1) * len) as u64;
        self.ledger.center_secs += ((parts.len() - 1) * len) as f64 * self.cost.t_add;
        self.ledger.rounds += 1;
        Ok(EncVec { scale, packed: None, data: EncData::Model(acc) })
    }

    fn add_plain(&mut self, v: &EncVec, plain: &[f64]) -> anyhow::Result<EncVec> {
        let vals = self.expect_model(v);
        let out: Vec<f64> = vals.iter().zip(plain).map(|(a, b)| a + b).collect();
        self.ledger.paillier_adds += plain.len() as u64;
        self.ledger.center_secs += plain.len() as f64 * self.cost.t_add;
        Ok(EncVec { scale: v.scale, packed: None, data: EncData::Model(out) })
    }

    fn to_shares(&mut self, v: &EncVec) -> anyhow::Result<SecVec> {
        anyhow::ensure!(
            v.scale == self.fmt.f,
            "to_shares expects scale-f ({}) values, got scale {}",
            self.fmt.f,
            v.scale
        );
        let vals = self.expect_model(v).to_vec();
        self.ledger.paillier_adds += vals.len() as u64;
        self.ledger.paillier_decrypts += vals.len() as u64;
        self.ledger.center_secs += vals.len() as f64 * (self.cost.t_add + self.cost.t_decrypt);
        self.ledger.bytes += vals.len() as u64 * self.ct_bytes;
        self.ledger.bytes_recv += vals.len() as u64 * self.ct_bytes;
        self.ledger.rounds += 2;
        Ok(SecVec::Model(vals))
    }

    fn decrypt_reveal(&mut self, v: &EncVec) -> Vec<f64> {
        let vals = self.expect_model(v).to_vec();
        self.ledger.paillier_decrypts += vals.len() as u64;
        self.ledger.center_secs += vals.len() as f64 * self.cost.t_decrypt;
        self.ledger.bytes += vals.len() as u64 * self.ct_bytes;
        self.ledger.bytes_recv += vals.len() as u64 * self.ct_bytes;
        self.ledger.rounds += 2;
        vals
    }

    fn newton_step(&mut self, h_tri: &SecVec, g: &SecVec, p: usize) -> Vec<f64> {
        self.charge_gc(ProgKind::Newton(p));
        let h = unpack_tri(self.expect_model_sec(h_tri), p);
        let g = self.expect_model_sec(g).to_vec();
        let x = h.solve_spd(&g).expect("modeled Hessian must be SPD");
        x.into_iter().map(|v| self.quant(v)).collect()
    }

    fn cholesky_shares(&mut self, h_tri: &SecVec, p: usize) -> SecVec {
        self.charge_gc(ProgKind::Cholesky(p));
        let h = unpack_tri(self.expect_model_sec(h_tri), p);
        let l = h.cholesky().expect("modeled Hessian must be SPD");
        let mut tri = Vec::with_capacity(tri_len(p));
        for i in 0..p {
            for j in 0..=i {
                tri.push(self.quant(l[(i, j)]));
            }
        }
        SecVec::Model(tri)
    }

    fn solve_reveal(&mut self, l_tri: &SecVec, g: &SecVec, p: usize) -> Vec<f64> {
        self.charge_gc(ProgKind::Solve(p));
        let lvals = self.expect_model_sec(l_tri);
        let mut l = Matrix::zeros(p, p);
        for i in 0..p {
            for j in 0..=i {
                l[(i, j)] = lvals[super::circuits::tri_idx(i, j)];
            }
        }
        let g = self.expect_model_sec(g).to_vec();
        l.solve_cholesky(&g).into_iter().map(|v| self.quant(v)).collect()
    }

    fn inverse_to_enc(&mut self, h_tri: &SecVec, p: usize) -> EncMat {
        self.charge_gc(ProgKind::Inverse(p));
        let h = unpack_tri(self.expect_model_sec(h_tri), p);
        let inv = h.inverse_spd().expect("modeled Hessian must be SPD");
        let mut tri = Vec::with_capacity(tri_len(p));
        for i in 0..p {
            for j in 0..=i {
                tri.push(self.quant(inv[(i, j)]));
            }
        }
        self.ledger.paillier_encs += tri_len(p) as u64;
        self.ledger.paillier_adds += tri_len(p) as u64;
        self.ledger.center_secs +=
            tri_len(p) as f64 * (self.cost.t_enc + self.cost.t_add);
        self.ledger.bytes += tri_len(p) as u64 * self.ct_bytes;
        self.ledger.bytes_recv += tri_len(p) as u64 * self.ct_bytes;
        self.ledger.rounds += 2;
        EncMat { p, tri: EncVec { scale: self.fmt.f, packed: None, data: EncData::Model(tri) } }
    }

    fn converged(&mut self, l_new: &SecVec, l_old: &SecVec, tol: f64) -> bool {
        self.charge_gc(ProgKind::Converged);
        let ln = self.expect_model_sec(l_new)[0];
        let lo = self.expect_model_sec(l_old)[0];
        (ln - lo).abs() < tol * lo.abs()
    }

    fn ledger(&self) -> &CostLedger {
        &self.ledger
    }
    fn ledger_mut(&mut self) -> &mut CostLedger {
        &mut self.ledger
    }
    fn cost_model(&self) -> &CostModel {
        &self.cost
    }
    fn backend_label(&self) -> &'static str {
        "modeled (calibrated cost model)"
    }
}

fn apply_hinv_model(fab: &ModelFabric, hinv: &EncMat, v: &[f64]) -> EncVec {
    let p = hinv.p;
    let tri = match &hinv.tri.data {
        EncData::Model(m) => m,
        _ => panic!("real EncMat in ModelFabric"),
    };
    let mut out = vec![0.0; p];
    for i in 0..p {
        for j in 0..p {
            let idx = if i >= j {
                super::circuits::tri_idx(i, j)
            } else {
                super::circuits::tri_idx(j, i)
            };
            // quantize the constant the same way the real path encodes it
            out[i] += tri[idx] * fab.quant(v[j]);
        }
    }
    EncVec { scale: 2 * fab.fmt.f, packed: None, data: EncData::Model(out) }
}

fn unpack_tri(tri: &[f64], p: usize) -> Matrix {
    assert_eq!(tri.len(), tri_len(p));
    let mut m = Matrix::zeros(p, p);
    for i in 0..p {
        for j in 0..=i {
            let v = tri[super::circuits::tri_idx(i, j)];
            m[(i, j)] = v;
            m[(j, i)] = v;
        }
    }
    m
}

/// Split a plaintext vector into additive shares (test/driver helper for
/// feeding GC ops directly).
pub fn share_vec(fmt: FixedFmt, vals: &[f64], rng: &mut ChaChaRng) -> Vec<Shared> {
    let mask = (1u128 << fmt.w) - 1;
    vals.iter()
        .map(|&v| {
            let raw = fmt.unsigned(fmt.encode(v));
            let a = ((rng.next_u64() as u128) << 64 | rng.next_u64() as u128) & mask;
            let b = raw.wrapping_sub(a) & mask;
            Shared { a, b }
        })
        .collect()
}

/// The `BigInt` import is used by signed plumbing in future extensions;
/// silence the lint until then.
#[allow(unused)]
fn _keep(_: BigInt) {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::{assert_all_close, TestRng};

    const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

    fn random_spd_tri(rng: &mut TestRng, p: usize) -> (Matrix, Vec<f64>) {
        let mut b = Matrix::zeros(p, p);
        for v in b.as_mut_slice() {
            *v = rng.gaussian() * 0.3;
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(1.0);
        let mut tri = Vec::new();
        for i in 0..p {
            for j in 0..=i {
                tri.push(a[(i, j)]);
            }
        }
        (a, tri)
    }

    /// Real fabric: Paillier encrypt → aggregate → to_shares → GC Newton
    /// step must equal the plaintext solve.
    #[test]
    fn real_fabric_newton_step_end_to_end() {
        let mut fab = RealFabric::new(256, FMT, 42);
        let mut rng = TestRng::new(5);
        let p = 3;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();

        // two "nodes" each contribute half of H and g
        let tri_half: Vec<f64> = tri.iter().map(|v| v / 2.0).collect();
        let g_half: Vec<f64> = g.iter().map(|v| v / 2.0).collect();
        let e1 = fab.node_encrypt_vec(0, &tri_half);
        let e2 = fab.node_encrypt_vec(1, &tri_half);
        let eh = fab.aggregate(vec![e1, e2]).unwrap();
        let g1 = fab.node_encrypt_vec(0, &g_half);
        let g2 = fab.node_encrypt_vec(1, &g_half);
        let eg = fab.aggregate(vec![g1, g2]).unwrap();
        let hs = fab.to_shares(&eh).unwrap();
        let gs = fab.to_shares(&eg).unwrap();
        let delta = fab.newton_step(&hs, &gs, p);
        assert_all_close(&delta, &expect, 1e-3, "secure newton step");
        assert!(fab.ledger().gc_ands > 0);
        assert!(fab.ledger().paillier_encs >= 12);
    }

    /// Malformed "node" input — mismatched ciphertext counts or scales —
    /// must surface as a session `Err` from aggregation, never a panic
    /// (one rogue node must not take the center down).
    #[test]
    fn aggregate_rejects_malformed_parts_without_panicking() {
        let mut fab = RealFabric::new(256, FMT, 46);
        let a = fab.node_encrypt_vec(0, &[1.0, 2.0]);
        let short = fab.node_encrypt_vec(1, &[1.0]);
        let err = fab.aggregate(vec![a.clone(), short]).unwrap_err().to_string();
        assert!(err.contains("length mismatch"), "{err}");
        let mut wrong_scale = fab.node_encrypt_vec(1, &[1.0, 2.0]);
        wrong_scale.scale = 99;
        let err = fab.aggregate(vec![a.clone(), wrong_scale]).unwrap_err().to_string();
        assert!(err.contains("scale mismatch"), "{err}");
        assert!(fab.aggregate(vec![]).is_err(), "empty aggregation is an error");
        // to_shares also rejects a wire-controlled scale, as Err.
        let mut bad = a;
        bad.scale = 7;
        assert!(fab.to_shares(&bad).is_err());
        // The fabric is still usable after the rejected rounds.
        let ok = fab.node_encrypt_vec(0, &[0.25]);
        assert_eq!(fab.decrypt_reveal(&ok), vec![0.25]);
    }

    /// Real fabric: cholesky_shares + solve_reveal == plaintext solve.
    #[test]
    fn real_fabric_cholesky_then_solve() {
        let mut fab = RealFabric::new(256, FMT, 43);
        let mut rng = TestRng::new(6);
        let p = 3;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();

        let eh = fab.node_encrypt_vec(0, &tri);
        let hs = fab.to_shares(&eh).unwrap();
        let ls = fab.cholesky_shares(&hs, p);
        let eg = fab.node_encrypt_vec(0, &g);
        let gs = fab.to_shares(&eg).unwrap();
        let x = fab.solve_reveal(&ls, &gs, p);
        assert_all_close(&x, &expect, 2e-3, "cholesky+solve");
    }

    /// Real fabric: inverse_to_enc → node_apply_hinv → decrypt_reveal
    /// equals H⁻¹·g (the full PrivLogit-Local data path).
    #[test]
    fn real_fabric_inverse_and_apply() {
        let mut fab = RealFabric::new(256, FMT, 44);
        let mut rng = TestRng::new(7);
        let p = 3;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.inverse_spd().unwrap().matvec(&g);

        let eh = fab.node_encrypt_vec(0, &tri);
        let hs = fab.to_shares(&eh).unwrap();
        let hinv = fab.inverse_to_enc(&hs, p);
        let applied = fab.node_apply_hinv(0, &hinv, &g);
        assert_eq!(applied.scale, 2 * FMT.f);
        let got = fab.decrypt_reveal(&applied);
        assert_all_close(&got, &expect, 2e-3, "Enc(H⁻¹)⊗g");
    }

    /// Split custody end-to-end against a remote center-b: every S2
    /// operation — relay-aggregate, blind (S2 keeps its halves), GC
    /// reveal, share-output Cholesky, solve over a remote-held `L`,
    /// masked inverse with S2-side encryption, convergence bit — matches
    /// the plaintext reference, and the control-frame census shows no
    /// share material ever crossed toward or from center-a.
    #[test]
    fn real_fabric_peer_custody_end_to_end() {
        use crate::mpc::peer::PeerGcServer;
        use crate::net::wire;

        let mut server = PeerGcServer::bind("127.0.0.1:0", 0x51).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve_once().unwrap());

        let mut fab = RealFabric::connect_peer(256, FMT, 47, &addr).unwrap();
        let mut rng = TestRng::new(13);
        let p = 3;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();

        // Aggregation is relayed to S2; blinding leaves S2's halves there.
        let tri_half: Vec<f64> = tri.iter().map(|v| v / 2.0).collect();
        let e1 = fab.node_encrypt_vec(0, &tri_half);
        let e2 = fab.node_encrypt_vec(1, &tri_half);
        let eh = fab.aggregate(vec![e1, e2]).unwrap();
        let hs = fab.to_shares(&eh).unwrap();
        match &hs {
            SecVec::Shares(sv) => {
                assert!(
                    matches!(sv.b, S2Custody::Remote { .. }),
                    "peer-link shares must leave S2 custody at center-b"
                );
            }
            SecVec::Model(_) => panic!("real fabric produced modeled shares"),
        }
        let eg = fab.node_encrypt_vec(0, &g);
        let gs = fab.to_shares(&eg).unwrap();

        // Revealing program over remote-held evaluator inputs.
        let delta = fab.newton_step(&hs, &gs, p);
        assert_all_close(&delta, &expect, 1e-3, "peer newton step");

        // Share-output program: S2 stores its L halves under a handle.
        let ls = fab.cholesky_shares(&hs, p);
        let x = fab.solve_reveal(&ls, &gs, p);
        assert_all_close(&x, &expect, 2e-3, "peer cholesky+solve");

        // Masked inverse: S2 assembles + encrypts its own wide outputs.
        let hinv = fab.inverse_to_enc(&hs, p);
        let applied = fab.node_apply_hinv(0, &hinv, &g);
        let got = fab.decrypt_reveal(&applied);
        let inv_expect = a.inverse_spd().unwrap().matvec(&g);
        assert_all_close(&got, &inv_expect, 2e-3, "peer Enc(H̃⁻¹)⊗g");

        // Convergence bit over two remote-held scalars.
        let e_old = fab.node_encrypt_vec(0, &[-0.5]);
        let e_new = fab.node_encrypt_vec(0, &[-0.5000000004]);
        let so = fab.to_shares(&e_old).unwrap();
        let sn = fab.to_shares(&e_new).unwrap();
        assert!(fab.converged(&sn, &so, 1e-6));

        // Custody census: the only frame that can carry S2 share values
        // is ShareInput, and it never appeared; S2-side work really ran.
        let census = fab.peer_census().expect("peer link");
        assert!(
            census.sent.get(&wire::TAG_SHARE_INPUT).is_none(),
            "share material crossed to center-b: {census:?}"
        );
        assert!(census.sent.get(&wire::TAG_AGGREGATE).copied().unwrap_or(0) >= 1);
        assert!(census.sent.get(&wire::TAG_BLIND).copied().unwrap_or(0) >= 4);
        assert!(census.recv.get(&wire::TAG_GC_OUT).copied().unwrap_or(0) >= 3);

        drop(fab); // sends Shutdown; center-b exits its session
        server_thread.join().unwrap();
    }

    #[test]
    fn real_fabric_converged() {
        let mut fab = RealFabric::new(256, FMT, 45);
        let e_old = fab.node_encrypt_vec(0, &[-0.5]);
        let e_new = fab.node_encrypt_vec(0, &[-0.5000000004]);
        let so = fab.to_shares(&e_old).unwrap();
        let sn = fab.to_shares(&e_new).unwrap();
        assert!(fab.converged(&sn, &so, 1e-6));
        let e_far = fab.node_encrypt_vec(0, &[-0.4]);
        let sf = fab.to_shares(&e_far).unwrap();
        assert!(!fab.converged(&sf, &so, 1e-6));
    }

    /// Model fabric mirrors the same data path with a virtual clock.
    #[test]
    fn model_fabric_mirrors_real_numerics() {
        let mut fab = ModelFabric::new(2048, FMT);
        let mut rng = TestRng::new(8);
        let p = 4;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();
        let eh = fab.node_encrypt_vec(0, &tri);
        let hs = fab.to_shares(&eh).unwrap();
        let eg = fab.node_encrypt_vec(0, &g);
        let gs = fab.to_shares(&eg).unwrap();
        let delta = fab.newton_step(&hs, &gs, p);
        assert_all_close(&delta, &expect, 1e-4, "modeled newton step");
        let l = fab.ledger();
        assert!(l.center_secs > 0.0, "virtual clock advanced");
        assert!(l.gc_ands > 0);
        fab.ledger_mut().end_node_round();
        assert!(fab.ledger().node_secs > 0.0);
    }

    /// The modeled per-iteration asymmetry the paper claims: solve ≪
    /// newton step ≪ in cost; apply_hinv cheapest of all.
    #[test]
    fn model_costs_reflect_paper_asymmetry() {
        let p = 16;
        let mut fab = ModelFabric::new(2048, FMT);
        let tri: Vec<f64> = {
            let mut rng = TestRng::new(9);
            random_spd_tri(&mut rng, p).1
        };
        let g = vec![0.1; p];
        let eh = fab.node_encrypt_vec(0, &tri);
        let hs = fab.to_shares(&eh).unwrap();
        let eg = fab.node_encrypt_vec(0, &g);
        let gs = fab.to_shares(&eg).unwrap();

        let c0 = fab.ledger().center_secs;
        fab.newton_step(&hs, &gs, p);
        let newton_cost = fab.ledger().center_secs - c0;

        let ls = fab.cholesky_shares(&hs, p);
        let c1 = fab.ledger().center_secs;
        fab.solve_reveal(&ls, &gs, p);
        let solve_cost = fab.ledger().center_secs - c1;

        assert!(
            solve_cost * 3.0 < newton_cost,
            "solve ({solve_cost}) must be ≪ newton ({newton_cost})"
        );
    }

    /// Gate counts are cubic in p — interpolation beyond the limit must
    /// be *exact*, not approximate.
    #[test]
    fn gate_count_interpolation_exact() {
        let mut fab = ModelFabric::new(2048, FMT);
        for p in [26usize, 30] {
            let interp = fab.gc_cost(ProgKind::Solve(p));
            let exact = count_prog(&SolveProg { p, fmt: FMT });
            assert_eq!(interp, exact, "solve p={p}");
            let interp = fab.gc_cost(ProgKind::Cholesky(p));
            let exact = count_prog(&CholeskyShareProg { p, fmt: FMT });
            assert_eq!(interp, exact, "cholesky p={p}");
        }
        // large p must be cheap to evaluate and strictly ordered
        let t0 = std::time::Instant::now();
        let (newton400, _) = fab.gc_cost(ProgKind::Newton(400));
        let (solve400, _) = fab.gc_cost(ProgKind::Solve(400));
        assert!(t0.elapsed().as_secs_f64() < 30.0, "interp path must be fast");
        assert!(newton400 > 50 * solve400, "p³ vs p² separation at p=400 (~p/6)");
    }

    /// The Straus multi-exp apply path is bit-identical to the naive
    /// reference loop for any worker count — including zero coefficients
    /// (skipped terms), negative coefficients (lazy inverse tables) and
    /// the ledger op counts.
    #[test]
    fn apply_hinv_matches_reference_bit_exact() {
        let mut rng = ChaChaRng::from_u64_seed(99);
        let kp = crate::crypto::paillier::Keypair::generate(256, &mut rng);
        let p = 5;
        let mut trng = TestRng::new(12);
        let tri: Vec<Ciphertext> = (0..tri_len(p))
            .map(|i| {
                kp.pk.encrypt(&BigUint::from_u64(1000 + i as u64), &mut ChaChaSource(&mut rng))
            })
            .collect();
        let v: Vec<f64> = (0..p)
            .map(|j| if j == 0 { 0.0 } else { trng.gaussian() })
            .collect();
        let (want, s_ref, a_ref) = apply_hinv_cts_reference(&kp.pk, FMT, p, &tri, &v);
        let prepared = PreparedHinv::prepare(&kp.pk, p, &tri, 2);
        assert_eq!(prepared.p(), p);
        for workers in [1usize, 4] {
            let (got, s, a) = prepared.apply(FMT, &v, workers);
            assert_eq!(got, want, "rows must be bit-identical (workers={workers})");
            assert_eq!((s, a), (s_ref, a_ref), "ledger counts (workers={workers})");
        }
        // All-zero coefficient vector: every row is the trivial zero.
        let zeros = vec![0.0; p];
        let (got0, s0, a0) = prepared.apply(FMT, &zeros, 2);
        let (want0, s0r, a0r) = apply_hinv_cts_reference(&kp.pk, FMT, p, &tri, &zeros);
        assert_eq!(got0, want0);
        assert_eq!((s0, a0), (s0r, a0r));
        // One-shot wrapper agrees too.
        let (got1, ..) = apply_hinv_cts(&kp.pk, FMT, p, &tri, &v);
        assert_eq!(got1, want);
    }

    #[test]
    fn share_vec_recombines() {
        let mut rng = ChaChaRng::from_u64_seed(3);
        let vals = [1.5, -2.25, 0.0, 100.125];
        let shares = share_vec(FMT, &vals, &mut rng);
        for (s, &v) in shares.iter().zip(&vals) {
            let sum = (s.a.wrapping_add(s.b)) & ((1u128 << FMT.w) - 1);
            assert_eq!(FMT.decode(sum as i128), FMT.decode(FMT.encode(v)));
        }
    }

    /// Packed fan-in (pack → homomorphic fold → plain add → reveal)
    /// decodes bit-identically to the unpacked legacy path over the
    /// same values — the central parity claim of the packing layer.
    #[test]
    fn packed_fan_in_matches_unpacked_bit_exact() {
        let mut fab = RealFabric::new(256, FMT, 50);
        assert!(fab.enable_packing(4, 3).unwrap(), "256-bit modulus must host 2 slots");
        let a = [1.5, -2.25, 0.125, 7.75, -0.0625];
        let b = [-0.5, 4.5, -3.125, 0.25, 9.0];
        let reg = [0.01, -0.02, 0.03, -0.04, 0.05];
        // Packed: 5 values in ⌈5/2⌉ = 3 ciphertexts per contribution.
        let pa = fab.encrypt_packed(&a).unwrap();
        let pb = fab.encrypt_packed(&b).unwrap();
        assert_eq!(pa.len(), 3, "5 values must pack into 3 ciphertexts at k=2");
        assert_eq!(pa.logical_len(), 5);
        let agg = fab.aggregate(vec![pa, pb]).unwrap();
        assert_eq!(agg.packed.unwrap().parts, 2, "aggregation sums contributions");
        let agg = fab.add_plain(&agg, &reg).unwrap();
        assert_eq!(agg.packed.unwrap().parts, 3, "plain add is one more contribution");
        let got = fab.decrypt_reveal(&agg);
        // Unpacked reference on the same fabric — the legacy path stays
        // callable alongside packing.
        let ua = fab.node_encrypt_vec(0, &a);
        let ub = fab.node_encrypt_vec(1, &b);
        let uagg = fab.aggregate(vec![ua, ub]).unwrap();
        let uagg = fab.add_plain(&uagg, &reg).unwrap();
        let want = fab.decrypt_reveal(&uagg);
        for (i, (g, w)) in got.iter().zip(&want).enumerate() {
            assert_eq!(g.to_bits(), w.to_bits(), "element {i}: packed vs unpacked decode");
        }
    }

    /// Packed ciphertexts cross the share boundary correctly: the
    /// per-slot blinds recombine into the same additive shares the
    /// unpacked conversion would produce, proven by running the GC
    /// Newton step on shares from a packed fan-in.
    #[test]
    fn packed_to_shares_feeds_newton_step() {
        let mut fab = RealFabric::new(256, FMT, 51);
        assert!(fab.enable_packing(4, 3).unwrap());
        let mut rng = TestRng::new(14);
        let p = 3;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();
        let tri_half: Vec<f64> = tri.iter().map(|v| v / 2.0).collect();
        let g_half: Vec<f64> = g.iter().map(|v| v / 2.0).collect();
        let e1 = fab.encrypt_packed(&tri_half).unwrap();
        let e2 = fab.encrypt_packed(&tri_half).unwrap();
        let eh = fab.aggregate(vec![e1, e2]).unwrap();
        let g1 = fab.encrypt_packed(&g_half).unwrap();
        let g2 = fab.encrypt_packed(&g_half).unwrap();
        let eg = fab.aggregate(vec![g1, g2]).unwrap();
        let hs = fab.to_shares(&eh).unwrap();
        let gs = fab.to_shares(&eg).unwrap();
        assert_eq!(hs.len(), tri_len(p), "shares are per logical element, not per ciphertext");
        assert_eq!(gs.len(), p);
        let delta = fab.newton_step(&hs, &gs, p);
        assert_all_close(&delta, &expect, 1e-3, "packed fan-in newton step");
    }

    /// Folding past the negotiated fan-in bound — by aggregation, by a
    /// plain add at the bound, or by mixing packed and unpacked parts —
    /// is a session error naming `fanin_sum`, never a silent slot carry.
    #[test]
    fn packed_fan_in_overflow_rejected() {
        let mut fab = RealFabric::new(256, FMT, 52);
        assert!(fab.enable_packing(2, 3).unwrap());
        let parts: Vec<EncVec> =
            (0..3).map(|_| fab.encrypt_packed(&[1.0, 2.0]).unwrap()).collect();
        let err = fab.aggregate(parts).unwrap_err().to_string();
        assert!(err.contains("fanin_sum"), "{err}");
        // At the bound: a 2-part aggregate is fine, one more plain add is not.
        let parts: Vec<EncVec> =
            (0..2).map(|_| fab.encrypt_packed(&[1.0, 2.0]).unwrap()).collect();
        let agg = fab.aggregate(parts).unwrap();
        let err = fab.add_plain(&agg, &[0.1, 0.2]).unwrap_err().to_string();
        assert!(err.contains("fanin_sum"), "{err}");
        // Mixing packed and unpacked parts is a session error too.
        let packed = fab.encrypt_packed(&[1.0, 2.0]).unwrap();
        let unpacked = fab.node_encrypt_vec(0, &[1.0, 2.0]);
        let err = fab.aggregate(vec![packed, unpacked]).unwrap_err().to_string();
        assert!(err.contains("packing mismatch"), "{err}");
        // The fabric still works after the rejections.
        let ok = fab.encrypt_packed(&[0.25, -0.25]).unwrap();
        assert_eq!(fab.decrypt_reveal(&ok), vec![0.25, -0.25]);
    }

    /// Packed split custody: center-b folds packed ciphertexts without
    /// layout knowledge, re-validates the self-describing packed Blind
    /// frame, draws one blind per slot, and the shares recombine in the
    /// GC exactly as in-process.
    #[test]
    fn packed_peer_custody_end_to_end() {
        use crate::mpc::peer::PeerGcServer;

        let mut server = PeerGcServer::bind("127.0.0.1:0", 0x52).unwrap();
        let addr = server.local_addr().unwrap().to_string();
        let server_thread = std::thread::spawn(move || server.serve_once().unwrap());

        let mut fab = RealFabric::connect_peer(256, FMT, 53, &addr).unwrap();
        assert!(fab.enable_packing(4, 3).unwrap());
        let mut rng = TestRng::new(15);
        let p = 3;
        let (a, tri) = random_spd_tri(&mut rng, p);
        let g: Vec<f64> = (0..p).map(|_| rng.gaussian()).collect();
        let expect = a.solve_spd(&g).unwrap();
        let tri_half: Vec<f64> = tri.iter().map(|v| v / 2.0).collect();
        let e1 = fab.encrypt_packed(&tri_half).unwrap();
        let e2 = fab.encrypt_packed(&tri_half).unwrap();
        let eh = fab.aggregate(vec![e1, e2]).unwrap();
        let eg = fab.encrypt_packed(&g).unwrap();
        let hs = fab.to_shares(&eh).unwrap();
        let gs = fab.to_shares(&eg).unwrap();
        assert_eq!(hs.len(), tri_len(p));
        let delta = fab.newton_step(&hs, &gs, p);
        assert_all_close(&delta, &expect, 1e-3, "packed peer newton step");
        drop(fab); // sends Shutdown; center-b exits its session
        server_thread.join().unwrap();
    }
}
