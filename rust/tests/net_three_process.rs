//! The paper's deployment diagram, literally runnable: center-a (garbler
//! + protocol driver), center-b (GC evaluator) and ≥3 organization node
//! servers as separate TCP endpoints on loopback — plus the failure
//! paths: a node dying mid-protocol must surface as a clean `Err`, and
//! the `privlogit center` CLI must exit non-zero without panicking.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::process::{Child, Command, Stdio};

use privlogit::bigint::BigUint;
use privlogit::coordinator::fleet::Fleet;
use privlogit::coordinator::{run_protocol, Backend, CenterLink};
use privlogit::data::{synthesize, Dataset};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::mpc::{PeerGcServer, RealFabric};
use privlogit::net::wire::{self, WireMsg};
use privlogit::net::{NodeServer, RemoteFleet, TcpTransport};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

/// One listening node server thread per partition; returns addresses.
fn spawn_node_servers(parts: Vec<Dataset>) -> Vec<String> {
    parts
        .into_iter()
        .enumerate()
        .map(|(j, shard)| {
            let mut server = NodeServer::bind("127.0.0.1:0", shard)
                .unwrap()
                .with_seed(0xD0DE ^ j as u64);
            let addr = server.local_addr().unwrap().to_string();
            std::thread::spawn(move || server.serve_once().unwrap());
            addr
        })
        .collect()
}

/// The tentpole topology: center-a + center-b + 3 node servers, all
/// separate TCP endpoints; real crypto; R² > 0.9999 vs plaintext.
/// Two custody proofs ride on the wire censuses:
///
/// * **fleet wire** — statistic replies were exclusively ciphertexts
///   (no plaintext statistic ever crossed), and
/// * **peer wire** — center-b held real S2 custody: it aggregated the
///   relayed node ciphertexts and kept its own blinds/shares, and no
///   frame carrying S2 share material (`ShareInput` is the only one
///   that can; `GcExec` references stored handles by construction)
///   ever crossed to or from center-a.
#[test]
fn three_center_split_ciphertext_only_fleet_wire() {
    let d = synthesize("split", 1200, 4, 90);
    let parts = d.partition(3);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    // Three node-server endpoints + the center-b S2 endpoint.
    let node_addrs = spawn_node_servers(parts);
    let mut peer = PeerGcServer::bind("127.0.0.1:0", 0xB0B).unwrap();
    let peer_addr = peer.local_addr().unwrap().to_string();
    let peer_thread = std::thread::spawn(move || peer.serve_once().unwrap());

    // Center-a: connects to everything and drives the protocol. Built
    // by hand (the same steps `run_protocol` takes for the real
    // backend) so the fabric — and with it the peer-wire census —
    // stays inspectable after the run.
    let mut fleet = RemoteFleet::connect(&node_addrs).unwrap();
    let mut fab = RealFabric::connect_peer(256, FMT, 0xA11CE, &peer_addr).unwrap();
    fleet.install_key(&fab.fleet_key()).unwrap();
    let report = Protocol::PrivLogitLocal.run(&mut fab, &mut fleet, &cfg).unwrap();

    assert!(report.converged, "converged across three processes");
    assert_eq!(report.orgs, 3);
    assert!(report.backend.contains("center-b"), "backend label: {}", report.backend);
    assert!(fleet.nodes_encrypt(), "real backend must install the key");
    let r2 = r_squared(&report.beta, &truth.beta);
    assert!(r2 > 0.9999, "R² = {r2} vs plaintext optimum");

    // Fleet-wire census: statistic replies were exclusively
    // ciphertexts. Metadata (Meta) and control acknowledgements (Ack)
    // are the only other reply tags; TAG_NODE_REPLY (plaintext
    // statistics) must never appear.
    let tags = fleet.reply_tag_counts();
    assert!(tags.get(&wire::TAG_NODE_REPLY).is_none(), "plaintext stats crossed: {tags:?}");
    assert!(tags.get(&wire::TAG_CIPHERTEXTS).copied().unwrap_or(0) > 0, "{tags:?}");
    for tag in tags.keys() {
        assert!(
            [wire::TAG_META, wire::TAG_ACK, wire::TAG_CIPHERTEXTS].contains(tag),
            "unexpected reply tag {tag:#04x} on the fleet wire: {tags:?}"
        );
    }

    // Peer-wire census: no S2 share material crossed to/from center-a.
    // Outbound, center-a sent only the public-key install, ciphertext
    // relays (Aggregate / Blind / inverse corrections as Ciphertexts)
    // and handle-referencing GcExec control frames — never a
    // ShareInput. Inbound, center-b answered with acks, ciphertexts
    // and revealed-by-design output bits — shares and blinds stayed
    // home. S2 really did the aggregation and blinding (frame counts
    // are positive).
    let census = fab.peer_census().expect("peer link must expose its census");
    assert!(
        census.sent.get(&wire::TAG_SHARE_INPUT).is_none(),
        "S2 share material crossed toward center-b: {census:?}"
    );
    let allowed_sent = [
        wire::TAG_SET_KEY,
        wire::TAG_AGGREGATE,
        wire::TAG_BLIND,
        wire::TAG_GC_EXEC,
        wire::TAG_CIPHERTEXTS,
    ];
    for tag in census.sent.keys() {
        assert!(
            allowed_sent.contains(tag),
            "unexpected frame {tag:#04x} center-a → center-b: {census:?}"
        );
    }
    for tag in census.recv.keys() {
        assert!(
            [wire::TAG_ACK, wire::TAG_CIPHERTEXTS, wire::TAG_GC_OUT].contains(tag),
            "unexpected frame {tag:#04x} center-b → center-a: {census:?}"
        );
    }
    assert!(census.sent.get(&wire::TAG_AGGREGATE).copied().unwrap_or(0) > 0, "{census:?}");
    assert!(census.sent.get(&wire::TAG_BLIND).copied().unwrap_or(0) > 0, "{census:?}");

    let net = fleet.net_stats();
    assert!(net.bytes_sent > 0 && net.bytes_recv > 0, "both directions: {net:?}");
    drop(fleet); // Shutdown to the nodes
    drop(fab); // PeerGcClient drop sends Shutdown to center-b
    peer_thread.join().unwrap();
}

/// A node that acks the key install but then replies with the wrong
/// number of ciphertexts must fail the run as a clean session error
/// naming the node — not a center panic (the old `assert_eq!` path in
/// the fabric's aggregation).
#[test]
fn malformed_node_reply_is_clean_error_not_panic() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
        assert_eq!(t.recv_wire().unwrap(), WireMsg::MetaReq);
        t.send_wire(&WireMsg::Meta { n: 300, p: 3, name: "rogue".into() }).unwrap();
        match t.recv_wire().unwrap() {
            WireMsg::SetKey { .. } => t.send_wire(&WireMsg::Ack).unwrap(),
            other => panic!("expected SetKey, got {other:?}"),
        }
        // Answer the Gram request with two ciphertexts where
        // tri_len(3) = 6 are expected.
        let _ = t.recv_wire().unwrap();
        t.send_wire(&WireMsg::Ciphertexts {
            scale: FMT.f,
            secs: 0.0,
            cts: vec![BigUint::one(), BigUint::one()],
        })
        .unwrap();
        let _ = t.recv_wire(); // hold the socket until the center gives up
    });

    let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
    let cfg = ProtocolConfig::default();
    let run = run_protocol(
        Protocol::PrivLogitHessian,
        Backend::Real,
        256,
        FMT,
        &cfg,
        7,
        &CenterLink::Mem,
        &mut fleet,
    );
    let err = run.expect_err("malformed reply must abort the run").to_string();
    assert!(err.contains("ciphertexts"), "error names the shape: {err}");
    assert!(err.contains("node 0"), "error names the node: {err}");
}

/// A fake node that answers the metadata handshake, then drops the
/// connection on the first statistic request.
fn spawn_dying_node() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
        assert_eq!(t.recv_wire().unwrap(), WireMsg::MetaReq);
        t.send_wire(&WireMsg::Meta { n: 300, p: 3, name: "dying".into() }).unwrap();
        // Wait for the first real request, then vanish mid-protocol.
        let _ = t.recv_wire();
    });
    addr
}

/// Killing a node mid-protocol yields `Err` from the fleet round — and
/// from the whole protocol run — naming the node, with no panic.
#[test]
fn node_death_mid_protocol_is_clean_error() {
    let addr = spawn_dying_node();
    let mut fleet = RemoteFleet::connect(&[addr.clone()]).unwrap();
    assert_eq!(fleet.p(), 3);

    let err = fleet.stats(&[0.0, 0.0, 0.0], 1.0 / 300.0).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("failed mid-protocol"), "error: {msg}");
    assert!(msg.contains(&addr), "error names the node: {msg}");

    // The same failure through the full protocol runner: Err, not panic.
    let addr2 = spawn_dying_node();
    let mut fleet2 = RemoteFleet::connect(&[addr2]).unwrap();
    let cfg = ProtocolConfig::default();
    let run = run_protocol(
        Protocol::PrivLogitHessian,
        Backend::Model,
        256,
        FMT,
        &cfg,
        1,
        &CenterLink::Mem,
        &mut fleet2,
    );
    assert!(run.is_err(), "protocol must surface the dead node as Err");
    assert!(run.unwrap_err().to_string().contains("failed mid-protocol"));
}

/// `privlogit center` against a node that dies mid-protocol: the process
/// exits non-zero with the error on stderr — no panic backtrace needed.
#[test]
fn center_cli_exits_nonzero_on_node_failure() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_privlogit") else {
        eprintln!("skipping: privlogit binary not built for this test harness");
        return;
    };
    let addr = spawn_dying_node();
    let out = Command::new(bin)
        .args(["center", "--nodes", &addr, "--backend", "model", "--protocol", "plh"])
        .output()
        .expect("run privlogit center");
    assert!(!out.status.success(), "center must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("failed mid-protocol"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "no panic on the node-failure path: {stderr}");
}

/// Reserve `k` distinct loopback ports (bind ephemeral, record, drop).
fn free_ports(k: usize) -> Vec<u16> {
    let listeners: Vec<TcpListener> =
        (0..k).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    listeners.iter().map(|l| l.local_addr().unwrap().port()).collect()
}

struct KillOnDrop(Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// The full CLI topology as five real OS processes: three `privlogit
/// node`, one `privlogit center-b --once`, one `privlogit center-a`.
/// The center-a report must show convergence and measured fleet wire
/// traffic; center-b must exit cleanly after its single session.
#[test]
fn five_process_cli_topology_end_to_end() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_privlogit") else {
        eprintln!("skipping: privlogit binary not built for this test harness");
        return;
    };
    let ports = free_ports(4);
    let dataset = "synth:n=900,p=3,seed=17";
    let mut nodes: Vec<KillOnDrop> = Vec::new();
    for org in 0..3 {
        let child = Command::new(bin)
            .args([
                "node",
                "--listen",
                &format!("127.0.0.1:{}", ports[org]),
                "--dataset",
                dataset,
                "--orgs",
                "3",
                "--org",
                &org.to_string(),
            ])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn node");
        nodes.push(KillOnDrop(child));
    }
    let peer_addr = format!("127.0.0.1:{}", ports[3]);
    let center_b = Command::new(bin)
        .args(["center-b", "--listen", &peer_addr, "--once"])
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn center-b");
    let mut center_b = KillOnDrop(center_b);

    let node_list = format!(
        "127.0.0.1:{},127.0.0.1:{},127.0.0.1:{}",
        ports[0], ports[1], ports[2]
    );
    let out = Command::new(bin)
        .args([
            "center-a",
            "--peer",
            &peer_addr,
            "--nodes",
            &node_list,
            "--protocol",
            "privlogit-local",
            "--backend",
            "real",
            "--modulus-bits",
            "256",
        ])
        .output()
        .expect("run center-a");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "center-a failed.\nstdout: {stdout}\nstderr: {stderr}");
    assert!(stdout.contains("converged: true"), "stdout: {stdout}");
    assert!(stdout.contains("fleet wire (measured)"), "stdout: {stdout}");

    // center-b was started with --once: it must exit on its own.
    let status = center_b.0.wait().expect("center-b wait");
    assert!(status.success(), "center-b --once must exit cleanly: {status:?}");
}

/// A rogue client speaking a different wire version is rejected before
/// any payload parsing — exercised against a real node server endpoint.
#[test]
fn node_rejects_version_skew() {
    let d = synthesize("skew", 60, 3, 3);
    let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.serve_once());
    let mut s = TcpStream::connect(addr).unwrap();
    let mut hello = wire::hello(wire::ROLE_CENTER);
    hello[4] = 0xFF; // future version
    hello[5] = 0x7F;
    s.write_all(&hello).unwrap();
    s.flush().unwrap();
    let result = server_thread.join().unwrap();
    let err = result.unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("version"), "got: {err}");
}
