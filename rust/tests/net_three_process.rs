//! The paper's deployment diagram, literally runnable: center-a (garbler
//! + protocol driver), center-b (GC evaluator) and ≥3 organization node
//! servers as separate TCP endpoints on loopback — plus the failure
//! paths: a node dying mid-protocol must surface as a clean `Err`, and
//! the `privlogit center` CLI must exit non-zero without panicking.

use std::collections::BTreeSet;
use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::process::{Child, Command, Stdio};
use std::time::Duration;

use privlogit::bigint::BigUint;
use privlogit::coordinator::fleet::Fleet;
use privlogit::coordinator::{run_protocol, Backend, CenterLink};
use privlogit::data::{synthesize, Dataset};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::mpc::{PeerGcServer, RealFabric, SecureFabric};
use privlogit::net::wire::{self, WireMsg};
use privlogit::net::{NodeServer, RemoteFleet, TcpTransport};
use privlogit::obs::json::{self as pjson, JsonValue};
use privlogit::obs::timeline::{parse_trace, Timeline, TraceEvent, TraceFile};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

/// One listening node server thread per partition; returns addresses.
fn spawn_node_servers(parts: Vec<Dataset>) -> Vec<String> {
    parts
        .into_iter()
        .enumerate()
        .map(|(j, shard)| {
            let mut server = NodeServer::bind("127.0.0.1:0", shard)
                .unwrap()
                .with_seed(0xD0DE ^ j as u64);
            let addr = server.local_addr().unwrap().to_string();
            std::thread::spawn(move || server.serve_once().unwrap());
            addr
        })
        .collect()
}

/// The tentpole topology: center-a + center-b + 3 node servers, all
/// separate TCP endpoints; real crypto; R² > 0.9999 vs plaintext.
/// Two custody proofs ride on the wire censuses:
///
/// * **fleet wire** — statistic replies were exclusively ciphertexts
///   (no plaintext statistic ever crossed), and
/// * **peer wire** — center-b held real S2 custody: it aggregated the
///   relayed node ciphertexts and kept its own blinds/shares, and no
///   frame carrying S2 share material (`ShareInput` is the only one
///   that can; `GcExec` references stored handles by construction)
///   ever crossed to or from center-a.
#[test]
fn three_center_split_ciphertext_only_fleet_wire() {
    let d = synthesize("split", 1200, 4, 90);
    let parts = d.partition(3);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    // Three node-server endpoints + the center-b S2 endpoint.
    let node_addrs = spawn_node_servers(parts);
    let mut peer = PeerGcServer::bind("127.0.0.1:0", 0xB0B).unwrap();
    let peer_addr = peer.local_addr().unwrap().to_string();
    let peer_thread = std::thread::spawn(move || peer.serve_once().unwrap());

    // Center-a: connects to everything and drives the protocol. Built
    // by hand (the same steps `run_protocol` takes for the real
    // backend) so the fabric — and with it the peer-wire census —
    // stays inspectable after the run.
    let mut fleet = RemoteFleet::connect(&node_addrs).unwrap();
    let mut fab = RealFabric::connect_peer(256, FMT, 0xA11CE, &peer_addr).unwrap();
    fleet.install_key(&fab.fleet_key()).unwrap();
    let report = Protocol::PrivLogitLocal.run(&mut fab, &mut fleet, &cfg).unwrap();

    assert!(report.converged, "converged across three processes");
    assert_eq!(report.orgs, 3);
    assert!(report.backend.contains("center-b"), "backend label: {}", report.backend);
    assert!(fleet.nodes_encrypt(), "real backend must install the key");
    let r2 = r_squared(&report.beta, &truth.beta);
    assert!(r2 > 0.9999, "R² = {r2} vs plaintext optimum");

    // Fleet-wire census: statistic replies were exclusively
    // ciphertexts. Metadata (Meta) and control acknowledgements (Ack)
    // are the only other reply tags; TAG_NODE_REPLY (plaintext
    // statistics) must never appear.
    let tags = fleet.reply_tag_counts();
    assert!(tags.get(&wire::TAG_NODE_REPLY).is_none(), "plaintext stats crossed: {tags:?}");
    assert!(tags.get(&wire::TAG_CIPHERTEXTS).copied().unwrap_or(0) > 0, "{tags:?}");
    for tag in tags.keys() {
        assert!(
            [wire::TAG_META, wire::TAG_ACK, wire::TAG_CIPHERTEXTS].contains(tag),
            "unexpected reply tag {tag:#04x} on the fleet wire: {tags:?}"
        );
    }

    // Peer-wire census: no S2 share material crossed to/from center-a.
    // Outbound, center-a sent only the public-key install, ciphertext
    // relays (Aggregate / Blind / inverse corrections as Ciphertexts)
    // and handle-referencing GcExec control frames — never a
    // ShareInput. Inbound, center-b answered with acks, ciphertexts
    // and revealed-by-design output bits — shares and blinds stayed
    // home. S2 really did the aggregation and blinding (frame counts
    // are positive).
    let census = fab.peer_census().expect("peer link must expose its census");
    assert!(
        census.sent.get(&wire::TAG_SHARE_INPUT).is_none(),
        "S2 share material crossed toward center-b: {census:?}"
    );
    let allowed_sent = [
        wire::TAG_SET_KEY,
        wire::TAG_AGGREGATE,
        wire::TAG_BLIND,
        wire::TAG_GC_EXEC,
        wire::TAG_CIPHERTEXTS,
    ];
    for tag in census.sent.keys() {
        assert!(
            allowed_sent.contains(tag),
            "unexpected frame {tag:#04x} center-a → center-b: {census:?}"
        );
    }
    for tag in census.recv.keys() {
        assert!(
            [wire::TAG_ACK, wire::TAG_CIPHERTEXTS, wire::TAG_GC_OUT].contains(tag),
            "unexpected frame {tag:#04x} center-b → center-a: {census:?}"
        );
    }
    assert!(census.sent.get(&wire::TAG_AGGREGATE).copied().unwrap_or(0) > 0, "{census:?}");
    assert!(census.sent.get(&wire::TAG_BLIND).copied().unwrap_or(0) > 0, "{census:?}");

    // Per-tag ledger accounting: every fleet frame is tagged (sent by
    // request tag, received by reply tag), so the per-tag byte sums
    // must equal the aggregate wire counters exactly. The peer-wire
    // flows cover control frames only (GC/OT streams stay untagged).
    let l = &report.ledger;
    assert_eq!(
        l.fleet_bytes_sent,
        l.fleet_tag_flows.values().map(|f| f.sent_bytes).sum::<u64>(),
        "fleet tag flows must partition sent bytes: {:?}",
        l.fleet_tag_flows
    );
    assert_eq!(
        l.fleet_bytes_recv,
        l.fleet_tag_flows.values().map(|f| f.recv_bytes).sum::<u64>(),
        "fleet tag flows must partition received bytes: {:?}",
        l.fleet_tag_flows
    );
    assert!(l.fleet_tag_flows[&wire::TAG_STEP_REQ].sent_frames > 0, "{:?}", l.fleet_tag_flows);
    assert!(l.peer_tag_flows[&wire::TAG_AGGREGATE].sent_frames > 0, "{:?}", l.peer_tag_flows);
    assert!(l.peer_tag_flows[&wire::TAG_GC_EXEC].sent_frames > 0, "{:?}", l.peer_tag_flows);

    let net = fleet.net_stats();
    assert!(net.bytes_sent > 0 && net.bytes_recv > 0, "both directions: {net:?}");
    drop(fleet); // Shutdown to the nodes
    drop(fab); // PeerGcClient drop sends Shutdown to center-b
    peer_thread.join().unwrap();
}

/// One full three-node topology (3 node servers + center-b + center-a)
/// running PrivLogit-Local over real TCP, packed or unpacked. Returns
/// the run report and the negotiated slot count `k` (0 when unpacked).
fn run_packed_topology(
    parts: Vec<Dataset>,
    cfg: &ProtocolConfig,
    packed: bool,
    seed: u64,
) -> (privlogit::protocols::RunReport, u32) {
    let node_addrs = spawn_node_servers(parts);
    let mut peer = PeerGcServer::bind("127.0.0.1:0", seed ^ 0xB0B).unwrap();
    let peer_addr = peer.local_addr().unwrap().to_string();
    let peer_thread = std::thread::spawn(move || peer.serve_once().unwrap());
    let mut fleet = RemoteFleet::connect(&node_addrs).unwrap();
    let mut fab = RealFabric::connect_peer(256, FMT, seed, &peer_addr).unwrap();
    let mut k = 0;
    if packed {
        // The coordinator's fan-in bound: one contribution per org plus
        // the regularizer `add_plain` and one spare (coordinator/mod.rs).
        let enabled = fab
            .enable_packing(fleet.orgs() as u64 + 2, fleet.p() as u64)
            .expect("a 256-bit modulus must host a packed layout at w = 40");
        assert!(enabled, "packing must engage at these parameters");
        k = fab.packing().expect("layout just enabled").k();
    }
    fleet.install_key(&fab.fleet_key()).unwrap();
    let report = Protocol::PrivLogitLocal.run(&mut fab, &mut fleet, cfg).unwrap();
    drop(fleet);
    drop(fab);
    peer_thread.join().unwrap();
    (report, k)
}

/// The tentpole's acceptance bar: the packed topology converges to the
/// plaintext optimum over real TCP while the statistic fan-in — the
/// Gram round; step replies are deliberately unpacked (honest scope,
/// docs/ARCHITECTURE.md §Packing) — costs at least (k/2)× fewer reply
/// bytes than the identical unpacked topology, and the per-tag byte
/// partition of the wire ledger still balances exactly in packed mode.
#[test]
fn packed_statistic_fanin_shrinks_wire_bytes() {
    let d = synthesize("packed-wire", 900, 4, 91);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &d.partition(3),
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    let (packed, k) = run_packed_topology(d.partition(3), &cfg, true, 0xA11CE);
    let (plain, _) = run_packed_topology(d.partition(3), &cfg, false, 0xFACE);
    assert!(k >= 2, "packing engaged with k = {k}");
    for (label, r) in [("packed", &packed), ("unpacked", &plain)] {
        assert!(r.converged, "{label} run must converge");
        let r2 = r_squared(&r.beta, &truth.beta);
        assert!(r2 > 0.9999, "{label} R² = {r2} vs plaintext optimum");
    }

    // Statistic-fan-in bytes: packed Gram replies carry ⌈tri/k⌉
    // ciphertexts instead of tri, so reply bytes shrink ≥ k/2 (framing
    // overhead eats part of the ideal k×):  2·unpacked ≥ k·packed.
    let gram_recv = |r: &privlogit::protocols::RunReport| -> u64 {
        r.ledger.fleet_tag_flows[&wire::TAG_GRAM_REQ].recv_bytes
    };
    assert!(
        2 * gram_recv(&plain) >= u64::from(k) * gram_recv(&packed),
        "Gram reply bytes must shrink ≥ (k/2)× = {k}/2: packed {} vs unpacked {}",
        gram_recv(&packed),
        gram_recv(&plain)
    );
    assert!(gram_recv(&packed) < gram_recv(&plain), "packed mode must strictly shrink");

    // The per-tag ledger partition holds for the packed wire too: every
    // frame is tagged, so the per-tag sums reproduce the aggregate
    // counters exactly — packing changed frame *sizes*, not accounting.
    let l = &packed.ledger;
    assert_eq!(
        l.fleet_bytes_sent,
        l.fleet_tag_flows.values().map(|f| f.sent_bytes).sum::<u64>(),
        "packed fleet tag flows must partition sent bytes: {:?}",
        l.fleet_tag_flows
    );
    assert_eq!(
        l.fleet_bytes_recv,
        l.fleet_tag_flows.values().map(|f| f.recv_bytes).sum::<u64>(),
        "packed fleet tag flows must partition received bytes: {:?}",
        l.fleet_tag_flows
    );
}

/// Packing is negotiated per session (wire v6 `SetKey`): the very same
/// node-server endpoints serve a packed center and then a `--no-pack`
/// center back-to-back, both topologies converge, and they agree on the
/// optimum — the fixed-point arithmetic is identical in both modes, so
/// the iterates match to rounding.
#[test]
fn packed_and_unpacked_topologies_interop() {
    let d = synthesize("interop", 900, 4, 92);
    let cfg = ProtocolConfig::default();

    // Node servers and center-b each serve two sequential sessions.
    let node_addrs: Vec<String> = d
        .partition(3)
        .into_iter()
        .enumerate()
        .map(|(j, shard)| {
            let mut server = NodeServer::bind("127.0.0.1:0", shard)
                .unwrap()
                .with_seed(0x1A7E ^ j as u64);
            let addr = server.local_addr().unwrap().to_string();
            std::thread::spawn(move || {
                for _ in 0..2 {
                    server.serve_once().unwrap();
                }
            });
            addr
        })
        .collect();
    let mut peer = PeerGcServer::bind("127.0.0.1:0", 0x5EED).unwrap();
    let peer_addr = peer.local_addr().unwrap().to_string();
    let peer_thread = std::thread::spawn(move || {
        for _ in 0..2 {
            peer.serve_once().unwrap();
        }
    });

    let run = |packed: bool, seed: u64| -> privlogit::protocols::RunReport {
        let mut fleet = RemoteFleet::connect(&node_addrs).unwrap();
        let mut fab = RealFabric::connect_peer(256, FMT, seed, &peer_addr).unwrap();
        if packed {
            assert!(fab.enable_packing(fleet.orgs() as u64 + 2, fleet.p() as u64).unwrap());
        }
        fleet.install_key(&fab.fleet_key()).unwrap();
        let report = Protocol::PrivLogitLocal.run(&mut fab, &mut fleet, &cfg).unwrap();
        drop(fleet);
        drop(fab);
        report
    };

    let packed = run(true, 0xC0FFEE);
    let plain = run(false, 0xDECAF);
    peer_thread.join().unwrap();

    assert!(packed.converged && plain.converged, "both sessions must converge");
    assert_eq!(packed.iterations, plain.iterations, "identical fixed-point trajectories");
    for (i, (a, b)) in packed.beta.iter().zip(&plain.beta).enumerate() {
        assert!(
            (a - b).abs() < 1e-12,
            "β[{i}] diverges between packed ({a}) and unpacked ({b}) sessions"
        );
    }
    // The negotiation really flipped modes: the packed session's Gram
    // fan-in crossed in strictly fewer reply bytes.
    let gram = |r: &privlogit::protocols::RunReport| -> u64 {
        r.ledger.fleet_tag_flows[&wire::TAG_GRAM_REQ].recv_bytes
    };
    assert!(gram(&packed) < gram(&plain), "packed {} vs unpacked {}", gram(&packed), gram(&plain));
}

/// A node that acks the key install but then replies with the wrong
/// number of ciphertexts must fail the run as a clean session error
/// naming the node — not a center panic (the old `assert_eq!` path in
/// the fabric's aggregation).
#[test]
fn malformed_node_reply_is_clean_error_not_panic() {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
        assert_eq!(t.recv_wire().unwrap(), WireMsg::MetaReq);
        t.send_wire(&WireMsg::Meta { n: 300, p: 3, name: "rogue".into() }).unwrap();
        match t.recv_wire().unwrap() {
            WireMsg::SetKey { .. } => t.send_wire(&WireMsg::Ack).unwrap(),
            other => panic!("expected SetKey, got {other:?}"),
        }
        // Answer the Gram request with two ciphertexts where
        // tri_len(3) = 6 are expected.
        let _ = t.recv_wire().unwrap();
        t.send_wire(&WireMsg::Ciphertexts {
            scale: FMT.f,
            secs: 0.0,
            cts: vec![BigUint::one(), BigUint::one()],
        })
        .unwrap();
        let _ = t.recv_wire(); // hold the socket until the center gives up
    });

    let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
    let cfg = ProtocolConfig::default();
    let run = run_protocol(
        Protocol::PrivLogitHessian,
        Backend::Real,
        256,
        FMT,
        &cfg,
        7,
        &CenterLink::Mem,
        &mut fleet,
    );
    let err = run.expect_err("malformed reply must abort the run").to_string();
    assert!(err.contains("ciphertexts"), "error names the shape: {err}");
    assert!(err.contains("node 0"), "error names the node: {err}");
}

/// A fake node that answers the metadata handshake, then drops the
/// connection on the first statistic request.
fn spawn_dying_node() -> String {
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    std::thread::spawn(move || {
        let (stream, _) = listener.accept().unwrap();
        let mut t = TcpTransport::accept(stream, wire::ROLE_NODE).unwrap();
        assert_eq!(t.recv_wire().unwrap(), WireMsg::MetaReq);
        t.send_wire(&WireMsg::Meta { n: 300, p: 3, name: "dying".into() }).unwrap();
        // Wait for the first real request, then vanish mid-protocol.
        let _ = t.recv_wire();
    });
    addr
}

/// Killing a node mid-protocol yields `Err` from the fleet round — and
/// from the whole protocol run — naming the node, with no panic.
#[test]
fn node_death_mid_protocol_is_clean_error() {
    let addr = spawn_dying_node();
    let mut fleet = RemoteFleet::connect(&[addr.clone()]).unwrap();
    assert_eq!(fleet.p(), 3);

    let err = fleet.stats(&[0.0, 0.0, 0.0], 1.0 / 300.0).unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("failed mid-protocol"), "error: {msg}");
    assert!(msg.contains(&addr), "error names the node: {msg}");

    // The same failure through the full protocol runner: Err, not panic.
    let addr2 = spawn_dying_node();
    let mut fleet2 = RemoteFleet::connect(&[addr2]).unwrap();
    let cfg = ProtocolConfig::default();
    let run = run_protocol(
        Protocol::PrivLogitHessian,
        Backend::Model,
        256,
        FMT,
        &cfg,
        1,
        &CenterLink::Mem,
        &mut fleet2,
    );
    assert!(run.is_err(), "protocol must surface the dead node as Err");
    assert!(run.unwrap_err().to_string().contains("failed mid-protocol"));
}

/// `privlogit center` against a node that dies mid-protocol: the process
/// exits non-zero with the error on stderr — no panic backtrace needed.
#[test]
fn center_cli_exits_nonzero_on_node_failure() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_privlogit") else {
        eprintln!("skipping: privlogit binary not built for this test harness");
        return;
    };
    let addr = spawn_dying_node();
    let out = Command::new(bin)
        .args(["center", "--nodes", &addr, "--backend", "model", "--protocol", "plh"])
        .output()
        .expect("run privlogit center");
    assert!(!out.status.success(), "center must exit non-zero");
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(stderr.contains("failed mid-protocol"), "stderr: {stderr}");
    assert!(!stderr.contains("panicked"), "no panic on the node-failure path: {stderr}");
}

/// Reserve `k` distinct loopback ports (bind ephemeral, record, drop).
fn free_ports(k: usize) -> Vec<u16> {
    let listeners: Vec<TcpListener> =
        (0..k).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    listeners.iter().map(|l| l.local_addr().unwrap().port()).collect()
}

struct KillOnDrop(Child);
impl Drop for KillOnDrop {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

/// Where this test's per-process trace files land: `PRIVLOGIT_TRACE_DIR`
/// when set (CI points it at a directory it uploads as an artifact),
/// otherwise a scratch directory.
fn trace_dir() -> PathBuf {
    match std::env::var("PRIVLOGIT_TRACE_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d),
        _ => std::env::temp_dir().join("privlogit_trace_test"),
    }
}

/// Poll `path` until it parses as a trace containing the node's final
/// `Shutdown` span — the node flushes its buffer at the session
/// boundary, which races with center-a's exit.
fn wait_for_shutdown_span(path: &Path) {
    let deadline = std::time::Instant::now() + Duration::from_secs(10);
    loop {
        if let Ok(text) = std::fs::read_to_string(path) {
            if let Ok(f) = parse_trace(&text) {
                if f.events.iter().any(|e| e.tag == Some(wire::TAG_SHUTDOWN)) {
                    return;
                }
            }
        }
        assert!(
            std::time::Instant::now() < deadline,
            "no Shutdown span appeared in {path:?} within 10s"
        );
        std::thread::sleep(Duration::from_millis(100));
    }
}

struct TracedRun {
    /// Parsed `--json` report from center-a (`privlogit-report/v1`).
    report: JsonValue,
    /// Trace files: node0, node1, node2, center-b, center-a.
    traces: Vec<PathBuf>,
}

/// Run the full five-process CLI topology (three `privlogit node`, one
/// `center-b --once`, one `center-a`) with `PRIVLOGIT_TRACE` set for
/// every process and `--json` report output.
fn run_traced_topology(bin: &str, dir: &Path, run_id: &str, seed: u64) -> TracedRun {
    let ports = free_ports(4);
    let dataset = "synth:n=900,p=3,seed=17";
    let node_traces: Vec<PathBuf> =
        (0..3).map(|j| dir.join(format!("{run_id}-node{j}.jsonl"))).collect();
    let mut nodes: Vec<KillOnDrop> = Vec::new();
    for org in 0..3 {
        let child = Command::new(bin)
            .args([
                "node",
                "--listen",
                &format!("127.0.0.1:{}", ports[org]),
                "--dataset",
                dataset,
                "--orgs",
                "3",
                "--org",
                &org.to_string(),
            ])
            .env("PRIVLOGIT_TRACE", &node_traces[org])
            .stdout(Stdio::null())
            .stderr(Stdio::null())
            .spawn()
            .expect("spawn node");
        nodes.push(KillOnDrop(child));
    }
    let peer_addr = format!("127.0.0.1:{}", ports[3]);
    let b_trace = dir.join(format!("{run_id}-center-b.jsonl"));
    let center_b = Command::new(bin)
        .args(["center-b", "--listen", &peer_addr, "--once"])
        .env("PRIVLOGIT_TRACE", &b_trace)
        .stdout(Stdio::null())
        .stderr(Stdio::null())
        .spawn()
        .expect("spawn center-b");
    let mut center_b = KillOnDrop(center_b);

    let node_list = format!(
        "127.0.0.1:{},127.0.0.1:{},127.0.0.1:{}",
        ports[0], ports[1], ports[2]
    );
    let a_trace = dir.join(format!("{run_id}-center-a.jsonl"));
    let out = Command::new(bin)
        .args([
            "center-a",
            "--peer",
            &peer_addr,
            "--nodes",
            &node_list,
            "--protocol",
            "privlogit-local",
            "--backend",
            "real",
            "--modulus-bits",
            "256",
            "--seed",
            &seed.to_string(),
            "--json",
        ])
        .env("PRIVLOGIT_TRACE", &a_trace)
        .output()
        .expect("run center-a");
    let stdout = String::from_utf8_lossy(&out.stdout);
    let stderr = String::from_utf8_lossy(&out.stderr);
    assert!(out.status.success(), "center-a failed.\nstdout: {stdout}\nstderr: {stderr}");
    let report = pjson::parse(stdout.trim()).expect("center-a --json output must parse");
    assert_eq!(report.get("schema").and_then(|v| v.as_str()), Some("privlogit-report/v1"));
    assert_eq!(report.get("converged").and_then(|v| v.as_bool()), Some(true), "{stdout}");

    // center-b was started with --once: it must exit on its own.
    let status = center_b.0.wait().expect("center-b wait");
    assert!(status.success(), "center-b --once must exit cleanly: {status:?}");
    // Nodes flush their traces when the fleet's Shutdown ends the
    // session; wait for that before killing them.
    for path in &node_traces {
        wait_for_shutdown_span(path);
    }
    drop(nodes);

    let mut traces = node_traces;
    traces.push(b_trace);
    traces.push(a_trace);
    TracedRun { report, traces }
}

/// The full CLI topology as five real OS processes, traced end to end,
/// run twice with different seeds. Checks the `--json` report schema,
/// that every process wrote a valid `privlogit-trace/v1` file, that the
/// merged timeline joins both ends of every wire on (session, tag,
/// round) with no cross-session bleed, that span counts match the
/// reported iteration count, and that the `privlogit trace` subcommand
/// merges the files into a timeline whose center-a rollup reproduces
/// the `CostLedger` wire totals exactly.
#[test]
fn five_process_cli_topology_end_to_end() {
    let Some(bin) = option_env!("CARGO_BIN_EXE_privlogit") else {
        eprintln!("skipping: privlogit binary not built for this test harness");
        return;
    };
    let dir = trace_dir();
    std::fs::create_dir_all(&dir).unwrap();
    let run_a = run_traced_topology(bin, &dir, "a", 42);
    let run_b = run_traced_topology(bin, &dir, "b", 43);

    let ledger = run_a.report.get("ledger").expect("report carries the ledger");
    let fleet_sent = ledger.get("fleet_bytes_sent").unwrap().as_u64().unwrap();
    let fleet_recv = ledger.get("fleet_bytes_recv").unwrap().as_u64().unwrap();
    assert!(fleet_sent > 0 && fleet_recv > 0);
    let iterations = run_a.report.get("iterations").unwrap().as_u64().unwrap();
    // The final convergence-only pass runs a node round and emits a
    // proto.iter span before breaking: rounds = iterations + 1.
    let expected_rounds = iterations + 1;

    let parse = |p: &PathBuf| -> TraceFile {
        let text = std::fs::read_to_string(p).unwrap_or_else(|e| panic!("read {p:?}: {e}"));
        parse_trace(&text).unwrap_or_else(|e| panic!("{p:?}: {e}"))
    };
    let files_a: Vec<TraceFile> = run_a.traces.iter().map(parse).collect();
    let files_b: Vec<TraceFile> = run_b.traces.iter().map(parse).collect();
    for (j, f) in files_a.iter().enumerate().take(3) {
        assert_eq!(f.proc, format!("node:{j}"));
    }
    assert_eq!(files_a[3].proc, "center-b");
    assert_eq!(files_a[4].proc, "center-a");

    // center-a's fleet.round spans partition the fleet wire: their byte
    // rollup must reproduce the ledger totals exactly (the Shutdown on
    // drop is deliberately outside both).
    let ca = &files_a[4];
    let rollup = |key: fn(&TraceEvent) -> u64| -> u64 {
        ca.events.iter().filter(|e| e.span == "fleet.round").map(key).sum()
    };
    assert_eq!(rollup(|e| e.bytes_sent), fleet_sent, "fleet.round sent-bytes rollup");
    assert_eq!(rollup(|e| e.bytes_recv), fleet_recv, "fleet.round recv-bytes rollup");

    // Per-tag frame counts: one fleet.rpc span per frame sent under a
    // request tag (the connect-time MetaReq predates the rpc spans).
    let flows = ledger.get("fleet_tag_flows").unwrap().as_arr().unwrap();
    assert!(!flows.is_empty());
    for flow in flows {
        let tag = flow.get("tag").unwrap().as_u64().unwrap() as u8;
        if tag == wire::TAG_META_REQ {
            continue;
        }
        let sent_frames = flow.get("sent_frames").unwrap().as_u64().unwrap();
        let rpcs = ca
            .events
            .iter()
            .filter(|e| e.span == "fleet.rpc" && e.tag == Some(tag))
            .count() as u64;
        assert_eq!(rpcs, sent_frames, "rpc span count vs ledger frames for tag {tag:#04x}");
    }

    // Span counts track the iteration count on both sides of the wire.
    let proto_iters = ca.events.iter().filter(|e| e.span == "proto.iter").count() as u64;
    assert_eq!(proto_iters, expected_rounds, "proto.iter spans");
    for nf in &files_a[0..3] {
        let steps = nf
            .events
            .iter()
            .filter(|e| e.span == "node.req" && e.tag == Some(wire::TAG_STEP_REQ))
            .count() as u64;
        assert_eq!(steps, expected_rounds, "StepReq spans on {}", nf.proc);
    }

    // Merged timeline across BOTH runs: the two seeds must produce two
    // distinct session ids, and within a session every (tag, round)
    // joins at most one span per (process, span name) — no duplicate
    // rounds, no cross-session bleed. fleet.rpc is the per-node fan-out
    // detail (three per round by design) and is skipped.
    let all: Vec<TraceFile> = files_a.iter().chain(files_b.iter()).cloned().collect();
    let timeline = Timeline::merge(all);
    let sessions: BTreeSet<&str> = timeline
        .events
        .iter()
        .map(|e| e.session.as_str())
        .filter(|s| *s != "-")
        .collect();
    assert_eq!(sessions.len(), 2, "two seeds → two session ids: {sessions:?}");
    let fleet_tags = [
        wire::TAG_SET_KEY,
        wire::TAG_GRAM_REQ,
        wire::TAG_SET_HINV,
        wire::TAG_STEP_REQ,
    ];
    for ((session, tag, round), ends) in timeline.per_round() {
        if session == "-" {
            continue;
        }
        let mut seen = BTreeSet::new();
        for e in ends.iter().filter(|e| e.span != "fleet.rpc") {
            assert!(
                seen.insert((e.proc.clone(), e.span.clone())),
                "duplicate {}:{} in round ({session}, {tag:#04x}, {round})",
                e.proc,
                e.span
            );
        }
        if fleet_tags.contains(&tag) {
            let node_ends = ends.iter().filter(|e| e.span == "node.req").count();
            assert_eq!(node_ends, 3, "all nodes served ({session}, {tag:#04x}, {round})");
            assert!(ends.iter().any(|e| e.span == "fleet.round"), "center end present");
        }
        if [wire::TAG_AGGREGATE, wire::TAG_BLIND, wire::TAG_GC_EXEC].contains(&tag) {
            assert!(ends.iter().any(|e| e.proc == "center-a"), "garbler end present");
            assert!(
                ends.iter().any(|e| e.proc == "center-b" && e.span == "peer.req"),
                "evaluator end present for ({session}, {tag:#04x}, {round})"
            );
        }
    }

    // The `privlogit trace` subcommand over all ten files.
    let paths: Vec<String> = run_a
        .traces
        .iter()
        .chain(run_b.traces.iter())
        .map(|p| p.to_str().unwrap().to_string())
        .collect();
    let out = Command::new(bin).arg("trace").arg("--validate").args(&paths).output().unwrap();
    assert!(out.status.success(), "trace --validate: {:?}", out);
    let validated = String::from_utf8_lossy(&out.stdout);
    assert_eq!(validated.lines().filter(|l| l.contains(": ok (")).count(), 10, "{validated}");

    let out = Command::new(bin).arg("trace").args(&paths).output().unwrap();
    assert!(out.status.success());
    let human = String::from_utf8_lossy(&out.stdout);
    assert!(human.contains("merged timeline"), "{human}");
    assert!(human.contains("StepReq"), "{human}");

    // --json over run A only: schema + the same ledger cross-check the
    // library rollup passed, now through the CLI end to end.
    let run_a_paths: Vec<String> =
        run_a.traces.iter().map(|p| p.to_str().unwrap().to_string()).collect();
    let out = Command::new(bin).arg("trace").arg("--json").args(&run_a_paths).output().unwrap();
    assert!(out.status.success());
    let doc = pjson::parse(String::from_utf8_lossy(&out.stdout).trim()).unwrap();
    assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some("privlogit-timeline/v1"));
    let phase = doc
        .get("phases")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .find(|p| {
            p.get("proc").and_then(|v| v.as_str()) == Some("center-a")
                && p.get("span").and_then(|v| v.as_str()) == Some("fleet.round")
        })
        .expect("center-a fleet.round phase in timeline JSON");
    assert_eq!(phase.get("bytes_sent").unwrap().as_u64(), Some(fleet_sent));
    assert_eq!(phase.get("bytes_recv").unwrap().as_u64(), Some(fleet_recv));
}

/// A rogue client speaking a different wire version is rejected before
/// any payload parsing — exercised against a real node server endpoint.
#[test]
fn node_rejects_version_skew() {
    let d = synthesize("skew", 60, 3, 3);
    let mut server = NodeServer::bind("127.0.0.1:0", d).unwrap();
    let addr = server.local_addr().unwrap();
    let server_thread = std::thread::spawn(move || server.serve_once());
    let mut s = TcpStream::connect(addr).unwrap();
    let mut hello = wire::hello(wire::ROLE_CENTER);
    hello[4] = 0xFF; // future version
    hello[5] = 0x7F;
    s.write_all(&hello).unwrap();
    s.flush().unwrap();
    let result = server_thread.join().unwrap();
    let err = result.unwrap_err();
    assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    assert!(err.to_string().contains("version"), "got: {err}");
}
