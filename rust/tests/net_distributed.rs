//! Distributed end-to-end tests: the Center and the organizations as
//! separate servers talking over real TCP loopback sockets — the
//! deployment shape of the paper's Figure 1 (its testbed was "two PCs on
//! ethernet"), which the in-process fleets only simulate.

use privlogit::coordinator::fleet::Fleet;
use privlogit::coordinator::{run_protocol, Backend, CenterLink};
use privlogit::data::{synthesize, Dataset};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::net::wire;
use privlogit::net::{NodeServer, RemoteFleet};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

/// One listening node server thread per partition; returns addresses.
fn spawn_node_servers(parts: Vec<Dataset>) -> Vec<String> {
    parts
        .into_iter()
        .map(|shard| {
            let mut server = NodeServer::bind("127.0.0.1:0", shard).unwrap();
            let addr = server.local_addr().unwrap().to_string();
            std::thread::spawn(move || server.serve_once().unwrap());
            addr
        })
        .collect()
}

/// PrivLogit-Local with REAL crypto, center ↔ 3 node servers over TCP:
/// must reproduce the plaintext optimum (R² > 0.9999) and report nonzero
/// wire bytes in both directions.
#[test]
fn privlogit_local_over_tcp_matches_plaintext() {
    let d = synthesize("net", 1200, 4, 77);
    let parts = d.partition(3);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    let addrs = spawn_node_servers(parts);
    let mut fleet = RemoteFleet::connect(&addrs).unwrap();
    let report = run_protocol(
        Protocol::PrivLogitLocal,
        Backend::Real,
        256,
        FMT,
        &cfg,
        0xD15,
        &CenterLink::Mem,
        &mut fleet,
    )
    .unwrap();

    assert!(report.converged, "converged over TCP");
    assert_eq!(report.orgs, 3);
    assert_eq!(report.n, 1200);
    assert_eq!(report.p, 4);
    assert!(report.engine.contains("remote fleet"), "engine: {}", report.engine);
    let r2 = r_squared(&report.beta, &truth.beta);
    assert!(r2 > 0.9999, "R² = {r2} vs plaintext optimum");

    let net = fleet.net_stats();
    assert!(net.bytes_sent > 0, "center sent requests: {net:?}");
    assert!(net.bytes_recv > 0, "center received replies: {net:?}");
    // Step rounds reply with two ciphertext frames per request, so
    // replies can outnumber requests.
    assert!(net.msgs_recv >= net.msgs_sent, "every request answered: {net:?}");
    // Real backend ⇒ the Paillier key was installed at the nodes ⇒ every
    // statistic reply was a ciphertext payload; no plaintext statistic
    // (TAG_NODE_REPLY) ever crossed the fleet wire.
    let tags = fleet.reply_tag_counts();
    assert!(tags.get(&wire::TAG_NODE_REPLY).is_none(), "plaintext stats crossed: {tags:?}");
    assert!(tags.get(&wire::TAG_CIPHERTEXTS).copied().unwrap_or(0) > 0, "{tags:?}");
    // The fleet traffic is folded into the report's ledger, in its own
    // measured-wire fields (the modeled `bytes` stay fleet-independent).
    assert_eq!(report.ledger.fleet_bytes_sent, net.bytes_sent);
    assert_eq!(report.ledger.fleet_bytes_recv, net.bytes_recv);
    assert!(report.ledger.bytes > 0 && report.ledger.bytes_recv > 0);
}

/// The full network shape: remote node fleet AND the two Center servers
/// linked over real TCP loopback sockets (garbled tables, OT messages
/// and decode bits all cross the kernel network stack). `Backend::Auto`
/// must resolve against the *fleet's* dimensionality.
#[test]
fn full_tcp_deployment_center_link_and_nodes() {
    let d = synthesize("net2", 900, 3, 78);
    let parts = d.partition(2);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::PrivLogit,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    let addrs = spawn_node_servers(parts);
    let mut fleet = RemoteFleet::connect(&addrs).unwrap();
    let report = run_protocol(
        Protocol::PrivLogitLocal,
        Backend::Auto, // p=3 ≤ REAL_P_LIMIT → real crypto
        256,
        FMT,
        &cfg,
        0xD16,
        &CenterLink::TcpLoopback, // center GC link over TCP loopback
        &mut fleet,
    )
    .unwrap();

    assert!(report.converged);
    assert!(
        report.backend.contains("tcp center link"),
        "backend label records the link: {}",
        report.backend
    );
    let r2 = r_squared(&report.beta, &truth.beta);
    assert!(r2 > 0.9999, "R² = {r2}");
    let net = fleet.net_stats();
    assert!(net.bytes_sent > 0 && net.bytes_recv > 0, "both directions: {net:?}");
}
