//! Property tests for the ciphertext slot-packing codec (ISSUE 10
//! satellite 1): a seeded-random sweep over (modulus bits, fixed-point
//! format, slot count k, node count n, magnitudes including the exact
//! slot maximum and negative totals) proving that
//! pack → homomorphic-sum → unpack equals the plaintext fixed-point
//! sums *bit-exactly*, and that every overflow-capable configuration is
//! rejected at session setup with an error naming the violated headroom
//! term (adversarial boundary: the layout one bit past each budget).

use privlogit::bigint::BigUint;
use privlogit::crypto::paillier::ChaChaSource;
use privlogit::crypto::{ChaChaRng, Keypair, PackError, PackedCodec, BLIND_SIGMA};
use privlogit::gc::word::FixedFmt;

/// Deterministic xorshift over the test's own seed stream so the sweep
/// is reproducible from the seed alone.
struct Sweep(ChaChaRng);

impl Sweep {
    fn below(&mut self, bound: u64) -> u64 {
        self.0.next_u64() % bound
    }
}

/// The plaintext reference: per-value fixed-point encodings
/// (`round(v·2^f)`, half away from zero — exactly what both
/// `FixedCodec::encode_scaled` and `PackedCodec::pack` compute), summed
/// as integers, decoded as `sum / 2^f`. Both halves are exact f64
/// operations at these magnitudes, so equality below is bit-equality.
fn plaintext_sums(vecs: &[Vec<f64>], f: u32) -> Vec<f64> {
    let len = vecs[0].len();
    let scale = (f as f64).exp2();
    (0..len)
        .map(|i| {
            let total: i128 = vecs
                .iter()
                .map(|v| {
                    let scaled = v[i] * scale;
                    scaled.abs().round() as i128 * if scaled < 0.0 { -1 } else { 1 }
                })
                .sum();
            total as f64 / scale
        })
        .collect()
}

/// Draw one per-node value vector: random magnitudes inside the
/// per-value budget, seasoned with the budget maximum in both signs so
/// slot boundaries are exercised, and enough negatives that some sums
/// wrap below zero. The budget is additionally capped at 2^48 so every
/// reference sum below stays exactly representable in f64 — the
/// dedicated slot-max test exercises the true `2^(w−1)−1` boundary.
fn draw_vec(sweep: &mut Sweep, len: usize, fmt: FixedFmt) -> Vec<f64> {
    let budget: u64 = (1u64 << (fmt.w - 1).min(48)) - 1;
    let scale = (fmt.f as f64).exp2();
    (0..len)
        .map(|i| {
            let mag = match sweep.below(8) {
                0 => budget, // exact slot max
                1 => 0,
                _ => sweep.below(budget),
            };
            let sign = if (i + sweep.below(2) as usize) % 2 == 0 { 1.0 } else { -1.0 };
            sign * mag as f64 / scale
        })
        .collect()
}

/// The tentpole property, over *real Paillier*: n nodes each pack and
/// encrypt a vector; the ciphertexts are folded homomorphically; the
/// decrypted plaintexts unpack to the bit-exact plaintext sums. Sweeps
/// formats, lengths (including non-multiples of k) and node counts.
#[test]
fn packed_homomorphic_sums_are_bit_exact() {
    let mut rng = ChaChaRng::from_u64_seed(0x5107_5107);
    let mut sweep = Sweep(ChaChaRng::from_u64_seed(0xFA71_1A5));
    for (modulus_bits, fmt) in [
        (384usize, FixedFmt { w: 40, f: 24 }),
        (384, FixedFmt { w: 32, f: 16 }),
        (512, FixedFmt { w: 48, f: 20 }),
        (512, FixedFmt { w: 24, f: 12 }),
    ] {
        let kp = Keypair::generate(modulus_bits, &mut rng);
        let real_bits = kp.pk.n.bit_len() as u32;
        for nodes in [2usize, 4, 7] {
            let max_parts = nodes as u64 + 2;
            let codec = match PackedCodec::plan(real_bits, fmt, max_parts, 8) {
                Ok(c) => c,
                Err(PackError::Capacity { .. }) => continue, // modulus too small: valid fallback
                Err(e) => panic!("plan must only fail with Capacity here: {e}"),
            };
            assert!(codec.k() >= 2, "a planned layout packs at least two slots");
            for len in [1usize, codec.k() as usize, codec.k() as usize * 2 + 1] {
                let vecs: Vec<Vec<f64>> =
                    (0..nodes).map(|_| draw_vec(&mut sweep, len, fmt)).collect();
                // Pack + encrypt per node, fold homomorphically.
                let mut acc: Option<Vec<privlogit::crypto::Ciphertext>> = None;
                for v in &vecs {
                    let ms = codec.pack(v, fmt.f).expect("in-budget values pack");
                    assert_eq!(ms.len(), codec.cts_needed(len));
                    let cts: Vec<_> =
                        ms.iter().map(|m| kp.pk.encrypt(m, &mut ChaChaSource(&mut rng))).collect();
                    acc = Some(match acc {
                        None => cts,
                        Some(a) => {
                            a.iter().zip(&cts).map(|(x, y)| kp.pk.add(x, y)).collect()
                        }
                    });
                }
                let ms: Vec<BigUint> =
                    acc.unwrap().iter().map(|ct| kp.sk.decrypt(ct)).collect();
                let got = codec
                    .unpack_vec(&ms, len, nodes as u128, fmt.f)
                    .expect("honest packed sum unpacks");
                let want = plaintext_sums(&vecs, fmt.f);
                for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                    assert_eq!(
                        g.to_bits(),
                        w.to_bits(),
                        "slot {i} of len={len} nodes={nodes} fmt={fmt:?}: {g} != {w}"
                    );
                }
            }
        }
    }
}

/// The same property on raw plaintexts (no encryption) over a much
/// larger seeded sweep — hundreds of random configurations, since each
/// trial is microseconds without Paillier. The homomorphic fold *is*
/// plaintext addition of packed integers, so this covers the codec's
/// arithmetic at volume while the test above pins the crypto round-trip.
#[test]
fn packed_plaintext_sum_sweep() {
    let mut sweep = Sweep(ChaChaRng::from_u64_seed(0xD15C_0DEC));
    let mut trials = 0;
    for _ in 0..400 {
        let w = 16 + sweep.below(48) as usize; // 16..64
        let f = sweep.below(w as u64 - 1) as u32; // f < w
        let fmt = FixedFmt { w, f };
        let nodes = 2 + sweep.below(9) as usize; // 2..=10
        let max_parts = nodes as u64 + sweep.below(3);
        let modulus_bits = 256 + sweep.below(4) as u32 * 256; // 256..1024
        let codec = match PackedCodec::plan(modulus_bits, fmt, max_parts, 1 + sweep.below(16)) {
            Ok(c) => c,
            Err(PackError::Capacity { .. }) => continue,
            Err(e) => panic!("plan must only fail with Capacity here: {e}"),
        };
        trials += 1;
        let len = 1 + sweep.below(codec.k() as u64 * 3) as usize;
        let vecs: Vec<Vec<f64>> = (0..nodes).map(|_| draw_vec(&mut sweep, len, fmt)).collect();
        let mut acc: Option<Vec<BigUint>> = None;
        for v in &vecs {
            let ms = codec.pack(v, fmt.f).expect("in-budget values pack");
            acc = Some(match acc {
                None => ms,
                Some(a) => a.iter().zip(&ms).map(|(x, y)| x.add(y)).collect(),
            });
        }
        let got = codec
            .unpack_vec(&acc.unwrap(), len, nodes as u128, fmt.f)
            .expect("honest packed sum unpacks");
        let want = plaintext_sums(&vecs, fmt.f);
        for (g, w) in got.iter().zip(&want) {
            assert_eq!(g.to_bits(), w.to_bits(), "fmt={fmt:?} nodes={nodes} len={len}");
        }
    }
    assert!(trials >= 100, "sweep must exercise at least 100 viable configs, got {trials}");
}

/// Adversarial setup boundaries: for each headroom term, the layout
/// exactly at the budget validates and the layout one bit past it is
/// rejected with an error *naming that term* — the codec never accepts
/// a configuration it cannot prove overflow-free.
#[test]
fn overflow_configs_rejected_at_setup_boundary() {
    let fmt = FixedFmt { w: 40, f: 24 };
    let w = fmt.w as u32;
    let max_parts = 6u64; // bitlen = 3
    let bitlen = 64 - max_parts.leading_zeros();
    let blind_need = w + bitlen + BLIND_SIGMA + 1; // the binding slot budget
    let roomy = 4096; // modulus comfortably larger than any layout here

    // Exactly at the blind_mask budget: accepted.
    let ok = PackedCodec::from_wire(roomy, fmt, 2, blind_need, max_parts)
        .expect("layout at the blind_mask budget is provably safe");
    assert_eq!(ok.slot_bits(), blind_need);

    // One bit short of each term, checked strongest-first so the error
    // names the *first violated* term in ascending order of strength.
    for (slot_bits, term) in [
        (w - 1, "per_value"),
        (w + bitlen - 1, "fanin_sum"),
        (blind_need - 1, "blind_mask"),
    ] {
        let err = PackedCodec::from_wire(roomy, fmt, 2, slot_bits, max_parts)
            .expect_err("under-budget slot must be rejected");
        let msg = err.to_string();
        assert!(msg.contains(term), "b={slot_bits}: error must name `{term}`, got: {msg}");
    }

    // modulus_capacity: k slots fit exactly at k·b + 2 = modulus bits;
    // one more slot (or one fewer modulus bit) is rejected by name.
    let k_fit = (roomy - 2) / blind_need;
    assert!(PackedCodec::from_wire(roomy, fmt, k_fit, blind_need, max_parts).is_ok());
    let err = PackedCodec::from_wire(roomy, fmt, k_fit + 1, blind_need, max_parts)
        .expect_err("k past the modulus capacity must be rejected");
    assert!(err.to_string().contains("modulus_capacity"), "got: {err}");
    let err = PackedCodec::from_wire(k_fit * blind_need + 1, fmt, k_fit, blind_need, max_parts)
        .expect_err("modulus one bit short must be rejected");
    assert!(err.to_string().contains("modulus_capacity"), "got: {err}");

    // k = 1 is not packing; the codec refuses to dress the legacy wire
    // up as a packed one.
    let err = PackedCodec::from_wire(roomy, fmt, 1, blind_need, max_parts)
        .expect_err("k = 1 must be rejected");
    assert!(err.to_string().contains("modulus_capacity"), "got: {err}");

    // hinv_apply: the center-side budget for Enc(H̃⁻¹)⊗g. At
    // 2w + ⌈log₂(max_parts·terms)⌉ + 1 it passes; one bit short names
    // the term.
    let terms = 12u64;
    let worst = (max_parts * terms) as u128;
    let hinv_need = 2 * w + (128 - worst.leading_zeros()) + 1;
    let at = PackedCodec::from_wire(roomy, fmt, 2, hinv_need.max(blind_need), max_parts).unwrap();
    at.apply_headroom(terms).expect("layout at the hinv_apply budget is safe");
    if hinv_need > blind_need {
        let under = PackedCodec::from_wire(roomy, fmt, 2, hinv_need - 1, max_parts).unwrap();
        let err = under.apply_headroom(terms).expect_err("one bit short must fail");
        assert!(err.to_string().contains("hinv_apply"), "got: {err}");
    }

    // plan() falls back with Capacity — and only Capacity — when the
    // modulus cannot host two slots (the coordinator's unpacked
    // fallback path), never by shrinking a headroom term.
    let err = PackedCodec::plan(2 * blind_need + 1, fmt, max_parts, 1)
        .expect_err("modulus one bit below two slots must be Capacity");
    assert!(matches!(err, PackError::Capacity { .. }), "got: {err}");
    assert!(PackedCodec::plan(2 * blind_need + 2, fmt, max_parts, 1).is_ok());
}

/// Runtime value boundary: the exact slot maximum `(2^(w−1)−1)/2^f`
/// packs in both signs; the first value that rounds to `2^(w−1)` is
/// rejected naming `per_value`, as are non-finite values.
#[test]
fn slot_max_packs_and_one_past_is_rejected() {
    let fmt = FixedFmt { w: 40, f: 24 };
    let codec = PackedCodec::plan(1024, fmt, 6, 8).unwrap();
    let scale = (fmt.f as f64).exp2();
    let max = ((1u64 << (fmt.w - 1)) - 1) as f64 / scale;
    codec.pack(&[max, -max], fmt.f).expect("exact slot max packs");
    for bad in [max + 1.0 / scale, -(max + 1.0 / scale), f64::NAN, f64::INFINITY] {
        let err = codec.pack(&[bad], fmt.f).expect_err("past-budget value must be rejected");
        assert!(err.to_string().contains("per_value"), "{bad}: got {err}");
    }
}

/// Fan-in boundary at runtime: a payload claiming exactly `max_parts`
/// contributions unpacks; `max_parts + 1` is rejected naming
/// `fanin_sum` — the unpack side enforces the same bound the aggregate
/// side does.
#[test]
fn unpack_fanin_boundary() {
    let fmt = FixedFmt { w: 40, f: 24 };
    let codec = PackedCodec::plan(1024, fmt, 3, 1).unwrap();
    let vecs: Vec<Vec<f64>> = (0..3).map(|i| vec![i as f64, -(i as f64)]).collect();
    let mut acc: Option<Vec<BigUint>> = None;
    for v in &vecs {
        let ms = codec.pack(v, fmt.f).unwrap();
        acc = Some(match acc {
            None => ms,
            Some(a) => a.iter().zip(&ms).map(|(x, y)| x.add(y)).collect(),
        });
    }
    let ms = acc.unwrap();
    let got = codec.unpack_vec(&ms, 2, 3, fmt.f).expect("at the fan-in bound unpacks");
    assert_eq!(got, plaintext_sums(&vecs, fmt.f));
    let err = codec.unpack_vec(&ms, 2, 4, fmt.f).expect_err("past the bound is rejected");
    assert!(err.to_string().contains("fanin_sum"), "got: {err}");
}
