//! End-to-end tests of `privlogit audit`: each seeded fixture tree
//! produces exactly its expected findings, the live crate tree audits
//! clean, and the CLI exit codes match what CI gates on.
//!
//! Deliberately no literal schema strings in this file — it is itself
//! inside the audit's schema census, so the expected tag is read from
//! `analysis::AUDIT_SCHEMA` instead.

use std::path::{Path, PathBuf};
use std::process::Command;

use privlogit::analysis::{self, Finding};

fn fixture(name: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("audit_fixtures").join(name)
}

fn audit_fixture(name: &str) -> Vec<Finding> {
    let report = analysis::audit(&fixture(name)).expect("fixture audit runs");
    assert!(!report.doc_found, "fixture trees must not see the repo docs");
    report.findings
}

#[test]
fn fixture_secret_flow() {
    let found = audit_fixture("bad_secret_flow");
    let lines: Vec<(usize, &str)> = found.iter().map(|f| (f.line, f.rule)).collect();
    assert_eq!(
        lines,
        vec![(5, "secret-flow"), (15, "secret-flow"), (22, "secret-flow"), (40, "secret-flow")],
        "{found:?}"
    );
    assert!(found.iter().all(|f| f.file == "keys.rs"), "{found:?}");
    assert!(found.iter().any(|f| f.message.contains("derives Debug")), "{found:?}");
    assert!(found.iter().any(|f| f.message.contains("non-opaque Display")), "{found:?}");
    assert!(found.iter().any(|f| f.message.contains("sink")), "{found:?}");
}

#[test]
fn fixture_panic_free() {
    let found = audit_fixture("bad_panic");
    assert_eq!(found.len(), 6, "{found:?}");
    assert!(found.iter().all(|f| f.file == "net/server.rs"), "{found:?}");
    let panic_lines: Vec<usize> =
        found.iter().filter(|f| f.rule == "panic-free").map(|f| f.line).collect();
    assert_eq!(panic_lines, vec![5, 6, 8, 10, 11], "{found:?}");
    let allows: Vec<&Finding> = found.iter().filter(|f| f.rule == "audit-allow").collect();
    assert_eq!(allows.len(), 1, "{found:?}");
    assert_eq!(allows[0].line, 14);
}

#[test]
fn fixture_wire_tags() {
    let found = audit_fixture("bad_wire_tags");
    assert_eq!(found.len(), 3, "{found:?}");
    assert!(found.iter().all(|f| f.rule == "wire-tags" && f.file == "net/wire.rs"), "{found:?}");
    assert!(found.iter().any(|f| f.line == 5 && f.message.contains("round-trip")), "{found:?}");
    assert!(found.iter().any(|f| f.line == 6 && f.message.contains("tag_name")), "{found:?}");
    assert!(found.iter().any(|f| f.line == 6 && f.message.contains("fn tag()")), "{found:?}");
}

#[test]
fn fixture_span_schema() {
    let found = audit_fixture("bad_spans");
    assert_eq!(found.len(), 2, "{found:?}");
    assert!(
        found.iter().all(|f| f.rule == "span-schema" && f.file == "obs/caller.rs"),
        "{found:?}"
    );
    assert!(
        found.iter().any(|f| f.line == 3 && f.message.contains("conflicting versions")),
        "{found:?}"
    );
    assert!(found.iter().any(|f| f.line == 8 && f.message.contains("proto.mystery")), "{found:?}");
}

#[test]
fn live_tree_is_clean() {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"));
    let report = analysis::audit(root).expect("self-audit runs");
    assert!(report.doc_found, "docs/ARCHITECTURE.md should be visible from the crate root");
    assert!(report.findings.is_empty(), "live tree has findings:\n{}", report.render_human());
    assert!(report.files_scanned > 50, "suspiciously few files scanned: {}", report.files_scanned);
}

#[test]
fn cli_exits_nonzero_on_fixture_and_zero_on_live_tree() {
    let bin = env!("CARGO_BIN_EXE_privlogit");
    let out =
        Command::new(bin).arg("audit").arg(fixture("bad_panic")).output().expect("audit runs");
    assert_eq!(out.status.code(), Some(1), "fixture audit should exit 1");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(text.contains("net/server.rs:5: panic-free:"), "{text}");
    assert!(text.contains("finding(s)"), "{text}");

    let out = Command::new(bin)
        .arg("audit")
        .arg("--json")
        .arg(env!("CARGO_MANIFEST_DIR"))
        .output()
        .expect("audit runs");
    let text = String::from_utf8_lossy(&out.stdout);
    assert!(out.status.success(), "live-tree audit should exit 0:\n{text}");
    let doc = privlogit::obs::json::parse(text.trim()).expect("valid report json");
    assert_eq!(doc.get("schema").and_then(|v| v.as_str()), Some(analysis::AUDIT_SCHEMA));
    assert_eq!(doc.get("findings").and_then(|v| v.as_arr()).map(|a| a.len()), Some(0));
    assert_eq!(doc.get("doc_found").and_then(|v| v.as_bool()), Some(true));
}
