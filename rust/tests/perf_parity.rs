//! Cross-layer parity for the Paillier hot-path overhaul: every
//! optimized path must be bit-identical (or decrypt-identical where the
//! randomness representation legitimately differs) to its reference
//! implementation, for any worker-thread count — including a full
//! NodeServer session served single-threaded vs parallel.

use privlogit::bigint::BigUint;
use privlogit::coordinator::fleet::{Fleet, FleetKey, NodePayload};
use privlogit::crypto::paillier::{ChaChaSource, Ciphertext, Keypair};
use privlogit::crypto::rng::ChaChaRng;
use privlogit::data::synthesize;
use privlogit::gc::word::FixedFmt;
use privlogit::mpc::fabric::{apply_hinv_cts, apply_hinv_cts_reference, PreparedHinv};
use privlogit::mpc::tri_len;
use privlogit::net::{NodeServer, RemoteFleet};

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

fn keypair(seed: u64) -> (Keypair, ChaChaRng) {
    let mut rng = ChaChaRng::from_u64_seed(seed);
    let kp = Keypair::generate(256, &mut rng);
    (kp, rng)
}

/// Fixed-base encryption and the generic-modpow reference produce
/// bit-identical ciphertexts on the same RNG stream, and the fast path
/// decrypts like full-range-randomness encryption.
#[test]
fn encryption_paths_agree() {
    let (kp, _) = keypair(41);
    let mut rng_a = ChaChaRng::from_u64_seed(7);
    let mut rng_b = ChaChaRng::from_u64_seed(7);
    for v in [0u64, 1, 999_999_937, u64::MAX] {
        let m = BigUint::from_u64(v);
        let fast = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng_a));
        let reference = kp.pk.encrypt_reference(&m, &mut ChaChaSource(&mut rng_b));
        assert_eq!(fast, reference, "bit parity at {v}");
        assert_eq!(kp.sk.decrypt(&fast), m, "roundtrip at {v}");
        let mut rng_c = ChaChaRng::from_u64_seed(v ^ 3);
        let full = kp.pk.encrypt_full(&m, &mut ChaChaSource(&mut rng_c));
        assert_eq!(kp.sk.decrypt(&full), m, "encrypt_full roundtrip at {v}");
    }
}

/// Straus multi-exp `apply_hinv` == naive reference, bit-exact, for any
/// worker count, with identical ledger op counts.
#[test]
fn apply_hinv_parity_random_triangles() {
    let (kp, mut rng) = keypair(42);
    for p in [1usize, 3, 7] {
        let tri: Vec<Ciphertext> = (0..tri_len(p))
            .map(|i| {
                let m = BigUint::from_u64((i as u64 + 1) * 7919);
                kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng))
            })
            .collect();
        let v: Vec<f64> = (0..p)
            .map(|j| match j % 4 {
                0 => 0.0,
                1 => 0.625,
                2 => -1.375,
                _ => 2.0,
            })
            .collect();
        let (want, s_ref, a_ref) = apply_hinv_cts_reference(&kp.pk, FMT, p, &tri, &v);
        let (got, s, a) = apply_hinv_cts(&kp.pk, FMT, p, &tri, &v);
        assert_eq!(got, want, "one-shot parity p={p}");
        assert_eq!((s, a), (s_ref, a_ref), "op counts p={p}");
        let prepared = PreparedHinv::prepare(&kp.pk, p, &tri, 3);
        for workers in [1usize, 2, 8] {
            let (rows, ..) = prepared.apply(FMT, &v, workers);
            assert_eq!(rows, want, "prepared parity p={p} workers={workers}");
        }
    }
}

/// Montgomery-resident aggregation and the fast `⊖` agree with their
/// references under decryption.
#[test]
fn aggregation_and_sub_parity() {
    let (kp, mut rng) = keypair(43);
    let cts: Vec<Ciphertext> = (1..=9u64)
        .map(|i| kp.pk.encrypt(&BigUint::from_u64(i * i), &mut ChaChaSource(&mut rng)))
        .collect();
    let refs: Vec<&Ciphertext> = cts.iter().collect();
    let folded = kp.pk.add_many(&refs);
    let mut chain = cts[0].clone();
    for c in &cts[1..] {
        chain = kp.pk.add(&chain, c);
    }
    assert_eq!(folded, chain, "add_many bit parity");
    let a = &cts[0];
    let b = &cts[1];
    assert_eq!(
        kp.sk.decrypt(&kp.pk.sub(a, b)),
        kp.sk.decrypt(&kp.pk.sub_reference(a, b)),
        "sub decrypt parity"
    );
}

/// A malformed `Enc(H̃⁻¹)` broadcast (non-invertible ciphertext) is a
/// clean session error on the node — not a worker-thread panic later in
/// the step round.
#[test]
fn malformed_hinv_broadcast_is_session_error() {
    let (kp, _) = keypair(45);
    let p = 3;
    let data = synthesize("bad", 60, p, 5);
    let mut server = NodeServer::bind("127.0.0.1:0", data).unwrap().with_seed(7);
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_once());
    let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
    let key = FleetKey { n: kp.pk.n.clone(), w: FMT.w as u32, f: FMT.f, packing: None };
    fleet.install_key(&key).unwrap();
    let mut cts: Vec<BigUint> = (0..tri_len(p)).map(|_| BigUint::one()).collect();
    cts[1] = BigUint::zero(); // gcd(0, n²) = n² — not a unit
    let res = fleet
        .install_hinv(&privlogit::coordinator::fleet::EncStat { scale: FMT.f, cts });
    assert!(res.is_err(), "node must reject a non-invertible broadcast");
    drop(fleet);
    // The session ended with an orderly Err; the server thread did NOT
    // panic (join succeeds and hands back the session result).
    let session = handle.join().expect("node thread must not panic");
    assert!(session.is_err(), "session must surface the broadcast error");
}

/// A NodeServer session served with parallel workers produces replies
/// byte-identical to a single-threaded session: same key, same node
/// seed, same requests — the per-node RNG stream is preserved because
/// randomness is drawn serially before the fan-out.
#[test]
fn node_server_parallel_replies_byte_identical() {
    let (kp, mut rng) = keypair(44);
    let p = 4;
    let data = synthesize("parity", 150, p, 77);
    let key = FleetKey { n: kp.pk.n.clone(), w: FMT.w as u32, f: FMT.f, packing: None };
    // A broadcastable Enc(H̃⁻¹) triangle (any valid ciphertexts work).
    let hinv_cts: Vec<BigUint> = (0..tri_len(p))
        .map(|i| {
            kp.pk
                .encrypt(&BigUint::from_u64(100 + i as u64), &mut ChaChaSource(&mut rng))
                .0
        })
        .collect();
    let beta = vec![0.05, -0.1, 0.2, 0.0];
    let scale = 1.0 / 150.0;

    let run = |threads: usize| -> (Vec<Vec<BigUint>>, Vec<BigUint>, Vec<BigUint>) {
        let mut server = NodeServer::bind("127.0.0.1:0", data.clone())
            .unwrap()
            .with_seed(99)
            .with_threads(threads);
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve_once().unwrap());
        let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
        fleet.install_key(&key).unwrap();
        let stats: Vec<Vec<BigUint>> = fleet
            .stats(&beta, scale)
            .unwrap()
            .into_iter()
            .map(|r| match r.payload {
                NodePayload::Enc(e) => e.cts,
                NodePayload::Plain { .. } => panic!("expected ciphertexts"),
            })
            .collect();
        fleet
            .install_hinv(&privlogit::coordinator::fleet::EncStat {
                scale: FMT.f,
                cts: hinv_cts.clone(),
            })
            .unwrap();
        let step = fleet.step(&beta, scale).unwrap().remove(0);
        drop(fleet);
        handle.join().unwrap();
        (stats, step.part.cts, step.loglik.cts)
    };

    let (stats_1, part_1, loglik_1) = run(1);
    let (stats_n, part_n, loglik_n) = run(4);
    assert_eq!(stats_1, stats_n, "statistic replies must be byte-identical");
    assert_eq!(part_1, part_n, "step partials must be byte-identical");
    assert_eq!(loglik_1, loglik_n, "loglik ciphertexts must be byte-identical");
}

/// Packed parity across the full NodeServer session (ISSUE 10
/// satellite 2): with a wire-negotiated packing layout, a node packs
/// its gradient into ⌈p/k⌉ ciphertexts and its Gram triangle into
/// ⌈tri_len/k⌉ — and the *decoded* statistics are bit-identical to the
/// legacy unpacked session on the same data, while the packed replies
/// themselves stay byte-identical across worker-thread counts
/// (`PRIVLOGIT_THREADS` 1 vs 4).
#[test]
fn packed_node_replies_decode_identical_to_unpacked() {
    use privlogit::crypto::fixed::FixedCodec;
    use privlogit::crypto::PackedCodec;

    let (kp, _) = keypair(47);
    let p = 5;
    let data = synthesize("packed-parity", 150, p, 79);
    let codec = PackedCodec::plan(kp.pk.n.bit_len() as u32, FMT, 3, p as u64)
        .expect("a 256-bit modulus hosts k = 2 at w = 40");
    assert!(codec.k() >= 2);
    let fixed = FixedCodec::new(kp.pk.n.clone(), FMT.f);
    let beta = vec![0.05, -0.1, 0.2, 0.0, 0.15];
    let scale = 1.0 / 150.0;

    // One session: install `key`, run a stats and a gram round, return
    // the raw reply ciphertexts.
    let run = |key: &FleetKey, threads: usize| -> (Vec<BigUint>, Vec<BigUint>) {
        let mut server = NodeServer::bind("127.0.0.1:0", data.clone())
            .unwrap()
            .with_seed(123)
            .with_threads(threads);
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve_once().unwrap());
        let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
        fleet.install_key(key).unwrap();
        let take = |r: privlogit::coordinator::fleet::NodeReply| match r.payload {
            NodePayload::Enc(e) => e.cts,
            NodePayload::Plain { .. } => panic!("expected ciphertexts"),
        };
        let stats = take(fleet.stats(&beta, scale).unwrap().remove(0));
        let gram = take(fleet.gram(scale).unwrap().remove(0));
        drop(fleet);
        handle.join().unwrap();
        (stats, gram)
    };

    let packed_key = FleetKey {
        n: kp.pk.n.clone(),
        w: FMT.w as u32,
        f: FMT.f,
        packing: Some(codec.params()),
    };
    let plain_key = FleetKey { n: kp.pk.n.clone(), w: FMT.w as u32, f: FMT.f, packing: None };

    let (packed_stats, packed_gram) = run(&packed_key, 1);
    let (packed_stats_n, packed_gram_n) = run(&packed_key, 4);
    assert_eq!(packed_stats, packed_stats_n, "packed stats byte-identical across threads");
    assert_eq!(packed_gram, packed_gram_n, "packed gram byte-identical across threads");
    let (plain_stats, plain_gram) = run(&plain_key, 1);

    // Shapes: the packed wire carries ⌈len/k⌉ ciphertexts (+ the
    // always-unpacked trailing loglik on the stats round).
    assert_eq!(packed_stats.len(), codec.cts_needed(p) + 1);
    assert_eq!(plain_stats.len(), p + 1);
    assert_eq!(packed_gram.len(), codec.cts_needed(tri_len(p)));
    assert_eq!(plain_gram.len(), tri_len(p));

    let decrypt = |cts: &[BigUint]| -> Vec<BigUint> {
        cts.iter().map(|c| kp.sk.decrypt(&Ciphertext(c.clone()))).collect()
    };
    let decode_plain =
        |cts: &[BigUint]| -> Vec<f64> { decrypt(cts).iter().map(|m| fixed.decode(m)).collect() };

    // Gradient: unpack the packed plaintexts, decode the unpacked ones
    // — bit-identical f64s.
    let packed_grad = codec
        .unpack_vec(&decrypt(&packed_stats[..codec.cts_needed(p)]), p, 1, FMT.f)
        .expect("fresh packed reply unpacks at parts = 1");
    let plain_grad = decode_plain(&plain_stats[..p]);
    for (i, (a, b)) in packed_grad.iter().zip(&plain_grad).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "gradient slot {i}: {a} != {b}");
    }
    // Loglik: its own unpacked ciphertext in both sessions.
    let ll_packed = decode_plain(&packed_stats[codec.cts_needed(p)..]);
    let ll_plain = decode_plain(&plain_stats[p..]);
    assert_eq!(ll_packed[0].to_bits(), ll_plain[0].to_bits(), "loglik share");
    // Gram triangle.
    let packed_tri = codec
        .unpack_vec(&decrypt(&packed_gram), tri_len(p), 1, FMT.f)
        .expect("fresh packed gram unpacks at parts = 1");
    let plain_tri = decode_plain(&plain_gram);
    for (i, (a, b)) in packed_tri.iter().zip(&plain_tri).enumerate() {
        assert_eq!(a.to_bits(), b.to_bits(), "gram slot {i}: {a} != {b}");
    }
}

/// A hostile packing layout in `SetKey` — one whose slots could
/// overflow — is rejected by the node at the trust boundary, naming the
/// violated headroom term; the session ends with an error instead of a
/// silently wrapping statistic.
#[test]
fn node_rejects_overflowing_packed_layout() {
    use privlogit::crypto::PackingParams;
    let (kp, _) = keypair(48);
    let data = synthesize("hostile", 60, 3, 9);
    let mut server = NodeServer::bind("127.0.0.1:0", data).unwrap().with_seed(11);
    let addr = server.local_addr().unwrap().to_string();
    let handle = std::thread::spawn(move || server.serve_once());
    let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
    // slot_bits = w: fits one value but not a fan-in of 6, and far too
    // small for the blind — the first violated term is fanin_sum.
    let key = FleetKey {
        n: kp.pk.n.clone(),
        w: FMT.w as u32,
        f: FMT.f,
        packing: Some(PackingParams { k: 2, slot_bits: FMT.w as u32, max_parts: 6 }),
    };
    assert!(fleet.install_key(&key).is_err(), "overflowing layout must fail the install");
    drop(fleet);
    let session = handle.join().expect("node thread must not panic");
    let err = session.expect_err("session must surface the layout rejection");
    assert!(err.to_string().contains("packed layout"), "got: {err}");
}

/// Tracing is observational only: with the JSONL span exporter
/// force-enabled, a parallel node session still produces replies
/// byte-identical to the single-threaded session (tracing never draws
/// randomness or reorders work), and the emitted trace file validates
/// against the `privlogit-trace/v1` schema.
#[test]
fn tracing_preserves_byte_identical_parallelism() {
    let path = std::env::temp_dir()
        .join(format!("privlogit_parity_trace_{}.jsonl", std::process::id()));
    assert!(
        privlogit::obs::install_trace(path.to_str().unwrap()),
        "tracing must be on for this test"
    );

    let (kp, mut rng) = keypair(46);
    let p = 4;
    let data = synthesize("traced", 150, p, 78);
    let key = FleetKey { n: kp.pk.n.clone(), w: FMT.w as u32, f: FMT.f, packing: None };
    let hinv_cts: Vec<BigUint> = (0..tri_len(p))
        .map(|i| {
            kp.pk
                .encrypt(&BigUint::from_u64(200 + i as u64), &mut ChaChaSource(&mut rng))
                .0
        })
        .collect();
    let beta = vec![0.05, -0.1, 0.2, 0.0];
    let scale = 1.0 / 150.0;

    let run = |threads: usize| -> (Vec<Vec<BigUint>>, Vec<BigUint>, Vec<BigUint>) {
        let mut server = NodeServer::bind("127.0.0.1:0", data.clone())
            .unwrap()
            .with_seed(101)
            .with_threads(threads);
        let addr = server.local_addr().unwrap().to_string();
        let handle = std::thread::spawn(move || server.serve_once().unwrap());
        let mut fleet = RemoteFleet::connect(&[addr]).unwrap();
        fleet.install_key(&key).unwrap();
        let stats: Vec<Vec<BigUint>> = fleet
            .stats(&beta, scale)
            .unwrap()
            .into_iter()
            .map(|r| match r.payload {
                NodePayload::Enc(e) => e.cts,
                NodePayload::Plain { .. } => panic!("expected ciphertexts"),
            })
            .collect();
        fleet
            .install_hinv(&privlogit::coordinator::fleet::EncStat {
                scale: FMT.f,
                cts: hinv_cts.clone(),
            })
            .unwrap();
        let step = fleet.step(&beta, scale).unwrap().remove(0);
        drop(fleet);
        handle.join().unwrap();
        (stats, step.part.cts, step.loglik.cts)
    };

    let (stats_1, part_1, loglik_1) = run(1);
    let (stats_n, part_n, loglik_n) = run(4);
    assert_eq!(stats_1, stats_n, "statistic replies must be byte-identical under tracing");
    assert_eq!(part_1, part_n, "step partials must be byte-identical under tracing");
    assert_eq!(loglik_1, loglik_n, "loglik ciphertexts must be byte-identical under tracing");

    // The trace this run emitted is valid `privlogit-trace/v1` and
    // carries both wire ends (center fleet.round, node node.req) plus
    // the multi-worker pool span from the threads=4 session.
    privlogit::obs::flush();
    let text = std::fs::read_to_string(&path).unwrap();
    let trace = privlogit::obs::timeline::parse_trace(&text)
        .unwrap_or_else(|e| panic!("trace must validate: {e}"));
    assert!(trace.events.iter().any(|e| e.span == "fleet.round"), "center spans present");
    assert!(trace.events.iter().any(|e| e.span == "node.req"), "node spans present");
    assert!(trace.events.iter().any(|e| e.span == "pool.par_map"), "parallel section traced");
    let _ = std::fs::remove_file(&path);
}
