//! Security-property tests for the paper's §5.3 guarantees. These are
//! semi-honest-model sanity checks, not proofs — but they catch the
//! classic implementation failures (randomness reuse, unmasked reveals,
//! leaky shares) that void the composition argument.

use privlogit::bigint::{BigUint, RandomSource};
use privlogit::coordinator::fleet::LocalFleet;
use privlogit::crypto::paillier::{ChaChaSource, Keypair};
use privlogit::crypto::rng::ChaChaRng;
use privlogit::data::synthesize;
use privlogit::gc::word::FixedFmt;
use privlogit::mpc::{EncData, RealFabric, S2Custody, SecVec, SecureFabric};
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

/// Semantic security smoke: encrypting the same plaintext twice must give
/// different ciphertexts, under both the short-exponent (default) and
/// full-randomness encryption paths.
#[test]
fn ciphertexts_are_randomized() {
    let mut rng = ChaChaRng::from_u64_seed(1);
    let kp = Keypair::generate(512, &mut rng);
    let m = BigUint::from_u64(42);
    let c1 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
    let c2 = kp.pk.encrypt(&m, &mut ChaChaSource(&mut rng));
    let c3 = kp.pk.encrypt_full(&m, &mut ChaChaSource(&mut rng));
    assert_ne!(c1, c2, "short-exponent encryption must be probabilistic");
    assert_ne!(c1, c3);
    assert_eq!(kp.sk.decrypt(&c1), m);
    assert_eq!(kp.sk.decrypt(&c3), m);
}

/// Share hiding: each server's share of a converted value, taken alone,
/// must look uniform — encode two very different values and check the
/// per-server shares are not distinguishable by a crude statistic.
#[test]
fn to_shares_individual_shares_look_uniform() {
    let mut fab = RealFabric::new(256, FMT, 2);
    let reps = 64;
    let mut high_bits_a = [0u32; 2];
    let mut high_bits_b = [0u32; 2];
    for (k, v) in [0.0f64, 1000.0].iter().enumerate() {
        for _ in 0..reps {
            let e = fab.node_encrypt_vec(0, &[*v]);
            let s = fab.to_shares(&e).unwrap();
            let SecVec::Shares(sh) = s else { panic!() };
            // In-process fabric: S2's halves are local custody.
            let S2Custody::Local(bv) = &sh.b else { panic!("in-process custody is local") };
            // test the top bit of each share word
            if (sh.a[0] >> (FMT.w - 1)) & 1 == 1 {
                high_bits_a[k] += 1;
            }
            if (bv[0] >> (FMT.w - 1)) & 1 == 1 {
                high_bits_b[k] += 1;
            }
        }
    }
    // each counter should be ~reps/2 regardless of the value; a fixed
    // (unmasked) share would give 0 or reps deterministically.
    for counts in [high_bits_a, high_bits_b] {
        for (k, c) in counts.iter().enumerate() {
            assert!(
                (8..56).contains(c),
                "share top bit must look random (value {k}): {c}/{reps}"
            );
        }
    }
}

/// Reveal minimization: a full PrivLogit-Hessian run must decrypt only
/// the by-design-public values. Everything else stays ciphertext/shares.
#[test]
fn run_reveals_only_by_design_values() {
    let d = synthesize("sec", 600, 3, 3);
    let parts = d.partition(2);
    let cfg = ProtocolConfig::default();
    let mut fleet = LocalFleet::new(parts, Box::new(CpuCompute));
    let mut fab = RealFabric::new(256, FMT, 4);
    let rep = Protocol::PrivLogitHessian.run(&mut fab, &mut fleet, &cfg).unwrap();
    let l = &rep.ledger;
    // decrypts = share conversions (blinded; reveal nothing) only. The
    // coefficient update Δ comes out of the garbled circuit, not a
    // decryption, in PL-Hessian.
    let tri = 3 * (3 + 1) / 2;
    let per_iter_conversions = (3 + 1) as u64; // g (p) + loglik (1)
    let setup_conversions = tri as u64;
    let expected_max =
        setup_conversions + (rep.iterations as u64 + 1) * per_iter_conversions + 4;
    assert!(
        l.paillier_decrypts <= expected_max,
        "decrypt count {} exceeds the blinded-conversion budget {}",
        l.paillier_decrypts,
        expected_max
    );
}

/// The masked inverse (PL-Local setup) must not hand the evaluator the
/// raw H̃⁻¹ entries: the wide reveals carry a ≥2⁴⁰ statistical mask, so
/// across two runs with identical data the evaluator-side transcripts
/// differ while the decrypted result is identical.
#[test]
fn inverse_masking_is_fresh_per_run() {
    let d = synthesize("sec2", 500, 3, 5);
    let parts = d.partition(2);
    let run = |seed: u64| -> (Vec<u8>, Vec<f64>) {
        let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut fab = RealFabric::new(256, FMT, seed);
        let hinv = privlogit::protocols::privlogit_local::setup_inverse(
            &mut fab,
            &mut fleet,
            1.0,
            1.0 / 500.0,
        )
        .unwrap();
        let EncData::Real(cts) = &hinv.tri.data else { panic!() };
        let transcript: Vec<u8> = cts.iter().flat_map(|c| c.0.to_bytes_le()).collect();
        let vals = fab.decrypt_reveal(&hinv.tri);
        (transcript, vals)
    };
    let (t1, v1) = run(10);
    let (t2, v2) = run(11);
    assert_ne!(t1, t2, "ciphertext transcripts must differ across runs");
    for (a, b) in v1.iter().zip(&v2) {
        assert!((a - b).abs() < 1e-4, "decrypted H̃⁻¹ identical: {a} vs {b}");
    }
}

/// Key independence: two fabrics with different seeds produce unrelated
/// keys and still interoperate with the same protocol logic.
#[test]
fn independent_keys_same_results() {
    let d = synthesize("sec3", 600, 3, 6);
    let parts = d.partition(2);
    let cfg = ProtocolConfig::default();
    let mut betas = Vec::new();
    for seed in [100u64, 200] {
        let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut fab = RealFabric::new(256, FMT, seed);
        let rep = Protocol::PrivLogitLocal.run(&mut fab, &mut fleet, &cfg).unwrap();
        betas.push(rep.beta);
    }
    let r2 = privlogit::linalg::r_squared(&betas[0], &betas[1]);
    assert!(r2 > 0.999999, "results must be key-independent: R²={r2}");
}

/// ChaCha20 stream independence across protocol roles (no nonce/counter
/// collision between differently-seeded generators).
#[test]
fn rng_streams_disjoint() {
    let mut a = ChaChaRng::from_u64_seed(7);
    let mut b = ChaChaRng::from_u64_seed(8);
    let mut collisions = 0;
    for _ in 0..1000 {
        if a.next_u64() == b.next_u64() {
            collisions += 1;
        }
    }
    assert_eq!(collisions, 0);
}
