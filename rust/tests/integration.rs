//! Cross-module integration tests over the public API: the full secure
//! pipeline (real and modeled), the experiment runner, and the
//! paper-facing invariants that span layers.

use privlogit::config::Config;
use privlogit::coordinator::fleet::{Fleet, LocalFleet, ThreadedFleet};
use privlogit::coordinator::{Backend, Experiment};
use privlogit::data::{load_workload, synthesize, workload};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::mpc::{ModelFabric, RealFabric, SecureFabric};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::runtime::CpuCompute;

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

/// Real crypto, threaded node fleet, all three protocols on one dataset:
/// the deployment shape end to end.
#[test]
fn real_crypto_threaded_fleet_all_protocols() {
    let d = synthesize("integ", 900, 3, 77);
    let parts = d.partition(3);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );
    for proto in Protocol::ALL {
        let mut fleet = ThreadedFleet::spawn(parts.clone());
        let mut fab = RealFabric::new(256, FMT, 4242);
        let rep = proto.run(&mut fab, &mut fleet, &cfg).unwrap();
        assert!(rep.converged, "{}", proto.name());
        let r2 = r_squared(&rep.beta, &truth.beta);
        assert!(r2 > 0.9999, "{}: R²={r2}", proto.name());
        // communication must actually flow
        assert!(rep.ledger.bytes > 0);
        assert!(rep.ledger.rounds > 0);
    }
}

/// The modeled backend must agree with the real backend on iterates —
/// the property that licenses using it for paper-scale sweeps.
#[test]
fn model_backend_matches_real_backend_iterates() {
    let d = synthesize("integ2", 1200, 4, 78);
    let parts = d.partition(2);
    let cfg = ProtocolConfig::default();

    let mut fleet_r = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
    let mut fab_r = RealFabric::new(256, FMT, 99);
    let real = Protocol::PrivLogitLocal.run(&mut fab_r, &mut fleet_r, &cfg).unwrap();

    let mut fleet_m = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
    let mut fab_m = ModelFabric::new(2048, FMT);
    let model = Protocol::PrivLogitLocal.run(&mut fab_m, &mut fleet_m, &cfg).unwrap();

    assert!(
        (real.iterations as i64 - model.iterations as i64).abs() <= 1,
        "iteration parity: {} vs {}",
        real.iterations,
        model.iterations
    );
    let r2 = r_squared(&real.beta, &model.beta);
    assert!(r2 > 0.999999, "coefficient parity R²={r2}");
}

/// Varying the number of organizations must not change the fit (the
/// paper notes org count does not influence the secure computation).
#[test]
fn org_count_invariance() {
    let d = synthesize("integ3", 1500, 4, 79);
    let cfg = ProtocolConfig::default();
    let mut betas = Vec::new();
    for orgs in [2usize, 5, 15] {
        let mut fleet = LocalFleet::new(d.partition(orgs), Box::new(CpuCompute));
        let mut fab = ModelFabric::new(2048, FMT);
        let rep = Protocol::PrivLogitHessian.run(&mut fab, &mut fleet, &cfg).unwrap();
        betas.push((orgs, rep.iterations, rep.beta));
    }
    for w in betas.windows(2) {
        // per-node fixed-point quantization before aggregation can shift
        // the convergence iteration by one — the optimum is unchanged
        let di = (w[0].1 as i64 - w[1].1 as i64).abs();
        assert!(di <= 1, "iterations ~invariant to org count: {} vs {}", w[0].1, w[1].1);
        let r2 = r_squared(&w[0].2, &w[1].2);
        assert!(r2 > 0.9999, "orgs {} vs {}: R²={r2}", w[0].0, w[1].0);
    }
}

/// Regularization actually regularizes: larger λ shrinks the coefficients.
#[test]
fn lambda_shrinks_coefficients() {
    let d = synthesize("integ4", 1500, 5, 80);
    let parts = d.partition(3);
    let norm = |lambda: f64| {
        let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
        let mut fab = ModelFabric::new(2048, FMT);
        let cfg = ProtocolConfig { lambda, ..Default::default() };
        let rep = Protocol::PrivLogitLocal.run(&mut fab, &mut fleet, &cfg).unwrap();
        privlogit::linalg::norm2(&rep.beta)
    };
    let loose = norm(0.1);
    let tight = norm(2000.0);
    assert!(tight < loose * 0.5, "λ=2000 norm {tight} vs λ=0.1 norm {loose}");
}

/// Experiment runner + config file round trip through the public entry
/// point (what the CLI drives).
#[test]
fn experiment_from_config_file() {
    let dir = std::env::temp_dir().join("privlogit_integ");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.conf");
    std::fs::write(&path, "dataset = SimuX10\nprotocol = plh\nbackend = model\norgs = 6\n")
        .unwrap();
    let mut cfg = Config::default();
    cfg.load_file(path.to_str().unwrap()).unwrap();
    let exp = Experiment::from_config(&cfg).unwrap();
    assert_eq!(exp.effective_backend(), Backend::Model);
    let rep = exp.run().unwrap();
    assert!(rep.converged);
    assert_eq!(rep.orgs, 6);
    assert_eq!(rep.protocol, "privlogit-hessian");
}

/// Failure injection: an org count larger than the sample count must be
/// rejected loudly, not mangled.
#[test]
#[should_panic(expected = "orgs")]
fn too_many_orgs_panics() {
    let d = synthesize("integ5", 10, 2, 81);
    let _ = d.partition(11);
}

/// Ledger sanity across a full run: the PL-Local iteration loop must be
/// GC-free except convergence checks (the paper's core structural claim).
#[test]
fn pll_iterations_are_gc_light() {
    let d = load_workload(workload("SimuX10").unwrap());
    let parts = d.partition(4);
    let cfg = ProtocolConfig::default();

    let mut fleet = LocalFleet::new(parts.clone(), Box::new(CpuCompute));
    let mut fab = ModelFabric::new(2048, FMT);
    // setup only
    let hinv = privlogit::protocols::privlogit_local::setup_inverse(
        &mut fab,
        &mut fleet,
        cfg.lambda,
        1.0 / d.n() as f64,
    )
    .unwrap();
    let setup_ands = fab.ledger().gc_ands;
    assert!(setup_ands > 0);
    drop(hinv);

    let mut fleet2 = LocalFleet::new(parts, Box::new(CpuCompute));
    let mut fab2 = ModelFabric::new(2048, FMT);
    let rep = Protocol::PrivLogitLocal.run(&mut fab2, &mut fleet2, &cfg).unwrap();
    let total_ands = fab2.ledger().gc_ands;
    // per-iteration GC is only the 1-bit convergence circuit
    let per_iter = (total_ands - setup_ands) as f64 / rep.iterations as f64;
    assert!(
        per_iter < 100_000.0,
        "PL-Local per-iteration GC must be tiny (convergence only): {per_iter}"
    );
}

/// A LocalFleet must expose consistent topology metadata.
#[test]
fn fleet_metadata() {
    let d = load_workload(workload("Wine").unwrap());
    let fleet = LocalFleet::new(d.partition(7), Box::new(CpuCompute));
    assert_eq!(fleet.orgs(), 7);
    assert_eq!(fleet.p(), 12);
    assert_eq!(fleet.n_total(), 6497);
    assert_eq!(fleet.dataset_name(), "Wine");
}

/// Property test: random data-oblivious word programs evaluate identically
/// under real garbling (through OT, streaming, decode) and the plaintext
/// backend — the correctness contract of the whole GC engine.
#[test]
fn garbled_random_programs_match_plaintext() {
    use privlogit::gc::backend::GcBackend;
    use privlogit::gc::word::{self, Word};
    use privlogit::gc::{GcProgram, GcSession};
    use privlogit::testutil::TestRng;

    struct RandomProg {
        fmt: FixedFmt,
        ops: Vec<u8>,
    }
    impl GcProgram for RandomProg {
        fn inputs_garbler(&self) -> usize {
            2 * self.fmt.w
        }
        fn inputs_evaluator(&self) -> usize {
            2 * self.fmt.w
        }
        fn run<B: GcBackend>(&self, b: &mut B, ga: &[B::Wire], ea: &[B::Wire]) -> Vec<B::Wire> {
            let w = self.fmt.w;
            let mut regs: Vec<Word<B::Wire>> = vec![
                ga[..w].to_vec(),
                ga[w..].to_vec(),
                ea[..w].to_vec(),
                ea[w..].to_vec(),
            ];
            for (i, &op) in self.ops.iter().enumerate() {
                let a = regs[i % 4].clone();
                let x = regs[(i + 1) % 4].clone();
                let r = match op % 5 {
                    0 => word::add(b, &a, &x),
                    1 => word::sub(b, &a, &x),
                    2 => word::mul(b, &a, &x, self.fmt),
                    3 => {
                        let s = word::lt(b, &a, &x);
                        word::mux_word(b, s, &a, &x)
                    }
                    _ => word::sar_const(b, &a, 1),
                };
                regs[(i + 2) % 4] = r;
            }
            regs.into_iter().flatten().collect()
        }
    }

    let fmt = FixedFmt { w: 24, f: 12 };
    let mut session = GcSession::new(314159);
    let mut rng = TestRng::new(271828);
    for round in 0..6 {
        let prog = RandomProg {
            fmt,
            ops: (0..8).map(|_| rng.below_u64(256) as u8).collect(),
        };
        let bits = |r: &mut TestRng| -> Vec<bool> {
            (0..2 * fmt.w).map(|_| r.bernoulli(0.5)).collect()
        };
        let ga = bits(&mut rng);
        let ea = bits(&mut rng);
        let (got, stats) = session.execute(&prog, &ga, &ea);
        let mut pb = privlogit::gc::backend::PlainBackend;
        let expect = prog.run(&mut pb, &ga, &ea);
        assert_eq!(got, expect, "round {round} ({} ANDs)", stats.ands);
    }
}
