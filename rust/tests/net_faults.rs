//! Fault-injection integration tests: fleet rounds must survive slow,
//! dead and byzantine-slow nodes (per-round deadlines + quorum), retry
//! flaky connects, and attribute every exclusion to the right node and
//! round in the trace — all deterministically, via the
//! `testutil::faults` harness installed on real TCP node servers.

use std::path::{Path, PathBuf};
use std::sync::{mpsc, OnceLock};
use std::time::Duration;

use privlogit::coordinator::fleet::Fleet;
use privlogit::coordinator::{checkpoint, run_protocol, Backend, CenterLink};
use privlogit::data::{synthesize, Dataset};
use privlogit::gc::word::FixedFmt;
use privlogit::linalg::r_squared;
use privlogit::net::wire;
use privlogit::net::{FleetOptions, NodeServer, RemoteFleet};
use privlogit::obs;
use privlogit::obs::json;
use privlogit::obs::timeline::{parse_trace, Timeline};
use privlogit::optim::{fit, Method, OptimConfig};
use privlogit::protocols::{Protocol, ProtocolConfig};
use privlogit::testutil::faults::{FaultAction, FaultPlan};

const FMT: FixedFmt = FixedFmt { w: 40, f: 24 };

static TRACE: OnceLock<PathBuf> = OnceLock::new();

/// Install (once per process) and return this binary's trace file. Every
/// test calls this before touching the network so the span sink exists
/// before the first span fires; all tests share one file and filter by
/// their own node addresses.
fn trace_path() -> &'static Path {
    TRACE.get_or_init(|| {
        let dir = match std::env::var("PRIVLOGIT_TRACE_DIR") {
            Ok(d) if !d.is_empty() => PathBuf::from(d),
            _ => std::env::temp_dir().join("privlogit_faults_test"),
        };
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(format!("net_faults_{}.jsonl", std::process::id()));
        assert!(obs::install_trace(path.to_str().unwrap()), "trace install failed");
        path
    })
}

/// Run `f` on its own thread and panic if it takes longer than
/// `timeout` — a hung quorum path must fail the test run, never wedge it.
fn watchdog<T: Send + 'static>(timeout: Duration, f: impl FnOnce() -> T + Send + 'static) -> T {
    let (tx, rx) = mpsc::channel();
    std::thread::spawn(move || {
        let _ = tx.send(f());
    });
    rx.recv_timeout(timeout).expect("watchdog: fleet operation hung or died")
}

/// One node server per partition, each with its own fault plan; returns
/// the listen addresses. Server threads are detached — faulted sessions
/// may park forever by design.
fn spawn_fault_fleet(parts: Vec<Dataset>, plan_for: impl Fn(usize) -> FaultPlan) -> Vec<String> {
    parts
        .into_iter()
        .enumerate()
        .map(|(j, shard)| {
            let server = NodeServer::bind("127.0.0.1:0", shard).unwrap();
            let addr = server.local_addr().unwrap().to_string();
            let mut server = plan_for(j).install(server);
            std::thread::spawn(move || {
                let _ = server.serve_once();
            });
            addr
        })
        .collect()
}

/// Like [`spawn_fault_fleet`], but each server keeps accepting sessions
/// (`serve_forever`) — what kill-and-restart scenarios need: the dead
/// session ends, the server accepts the readmission probe's or the
/// resumed center's fresh connection.
fn spawn_persistent_fleet(
    parts: Vec<Dataset>,
    plan_for: impl Fn(usize) -> FaultPlan,
) -> Vec<String> {
    parts
        .into_iter()
        .enumerate()
        .map(|(j, shard)| {
            let server = NodeServer::bind("127.0.0.1:0", shard).unwrap();
            let addr = server.local_addr().unwrap().to_string();
            let mut server = plan_for(j).install(server);
            std::thread::spawn(move || {
                let _ = server.serve_forever();
            });
            addr
        })
        .collect()
}

/// A plan faulting the reply of the first `GramReq` — the setup round
/// both 16-node scenarios target.
fn gram_fault(action: FaultAction) -> FaultPlan {
    FaultPlan::new().on(wire::TAG_GRAM_REQ, 0, action)
}

/// The acceptance topology: 16 node servers, three of them faulted on
/// the Gram round (one hangs, one dies mid-frame, one straggles past the
/// deadline). At quorum 13 the PrivLogit-Local run must complete in
/// bounded time, match the plaintext optimum of the *surviving* subset,
/// and the trace must attribute each exclusion to the right node, round
/// and outcome.
#[test]
fn sixteen_nodes_three_faulted_quorum_thirteen_converges() {
    let trace = trace_path();
    let d = synthesize("faults16", 1600, 3, 91);
    let parts = d.partition(16);
    let cfg = ProtocolConfig::default();
    let survivors: Vec<Dataset> = parts
        .iter()
        .enumerate()
        .filter(|(j, _)| ![3, 7, 11].contains(j))
        .map(|(_, p)| p.clone())
        .collect();
    let truth = fit(
        &survivors,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );

    let addrs = spawn_fault_fleet(parts, |j| match j {
        3 => gram_fault(FaultAction::Hang),
        7 => gram_fault(FaultAction::TruncateFrame(5)),
        11 => gram_fault(FaultAction::Delay(Duration::from_secs(5))),
        _ => FaultPlan::new(),
    });
    let opts = FleetOptions {
        round_timeout: Some(Duration::from_secs(2)),
        quorum: 13,
        ..FleetOptions::default()
    };

    let run_addrs = addrs.clone();
    let (report, excluded, orgs_after, n_after) = watchdog(Duration::from_secs(240), move || {
        let mut fleet = RemoteFleet::connect_with(&run_addrs, opts).unwrap();
        let report = run_protocol(
            Protocol::PrivLogitLocal,
            Backend::Real,
            256,
            FMT,
            &cfg,
            0xFA1,
            &CenterLink::Mem,
            &mut fleet,
        )
        .unwrap();
        (report, fleet.excluded().to_vec(), fleet.orgs(), fleet.n_total())
    });

    assert!(report.converged, "quorum run converged");
    assert_eq!(orgs_after, 13, "three nodes excluded");
    assert_eq!(n_after, 1300, "n_total recomputed from live membership");
    let r2 = r_squared(&report.beta, &truth.beta);
    assert!(r2 > 0.9999, "R² = {r2} vs plaintext optimum of the surviving subset");
    assert_eq!(report.ledger.excluded_nodes, 3, "ledger counts the exclusions");

    // Exclusion records: right node, right round, right classification.
    assert_eq!(excluded.len(), 3, "{excluded:?}");
    for (idx, want) in [(3usize, "timeout"), (7, "error"), (11, "timeout")] {
        let e = excluded
            .iter()
            .find(|e| e.addr == addrs[idx])
            .unwrap_or_else(|| panic!("no exclusion record for node {idx}: {excluded:?}"));
        assert_eq!(e.outcome, want, "{e:?}");
        assert_eq!((e.tag, e.round, e.org), (wire::TAG_GRAM_REQ, 0, idx), "{e:?}");
    }

    // The trace tells the same story: one fleet.rpc span per faulted
    // node on GramReq round 0, with the matching outcome.
    obs::flush();
    let file = parse_trace(&std::fs::read_to_string(trace).unwrap()).unwrap();
    for (idx, want) in [(3usize, "timeout"), (7, "error"), (11, "timeout")] {
        let ev = file
            .events
            .iter()
            .find(|e| {
                e.span == "fleet.rpc"
                    && e.node.as_deref() == Some(addrs[idx].as_str())
                    && e.tag == Some(wire::TAG_GRAM_REQ)
            })
            .unwrap_or_else(|| panic!("no GramReq fleet.rpc span for node {idx}"));
        assert_eq!(ev.outcome.as_deref(), Some(want), "node {idx}: {ev:?}");
        assert_eq!(ev.round, Some(0), "node {idx}: {ev:?}");
    }
}

/// The same three-fault topology at strict (default) quorum: the session
/// must fail fast with an error naming all three dead nodes — bounded by
/// the round deadline, no panic, no hang.
#[test]
fn sixteen_nodes_three_faulted_strict_quorum_fails_naming_all() {
    trace_path();
    let d = synthesize("faults16s", 1600, 3, 92);
    let parts = d.partition(16);
    let addrs = spawn_fault_fleet(parts, |j| match j {
        3 => gram_fault(FaultAction::Hang),
        7 => gram_fault(FaultAction::TruncateFrame(5)),
        11 => gram_fault(FaultAction::Delay(Duration::from_secs(5))),
        _ => FaultPlan::new(),
    });
    // quorum 0 (the default) = every live node must reply.
    let opts = FleetOptions {
        round_timeout: Some(Duration::from_secs(2)),
        ..FleetOptions::default()
    };

    let cfg = ProtocolConfig::default();
    let run_addrs = addrs.clone();
    let err = watchdog(Duration::from_secs(240), move || {
        let mut fleet = RemoteFleet::connect_with(&run_addrs, opts).unwrap();
        run_protocol(
            Protocol::PrivLogitLocal,
            Backend::Real,
            256,
            FMT,
            &cfg,
            0xFA2,
            &CenterLink::Mem,
            &mut fleet,
        )
        .expect_err("strict quorum must abort on the first missed round")
    });
    let msg = format!("{err:#}");
    assert!(msg.contains("failed mid-protocol"), "error: {msg}");
    assert!(msg.contains("quorum"), "error states the quorum shortfall: {msg}");
    for idx in [3, 7, 11] {
        assert!(msg.contains(&addrs[idx]), "error names node {idx} ({}): {msg}", addrs[idx]);
    }
}

/// A node whose listener drops the first k connection attempts
/// pre-handshake: the center's bounded connect retry (exponential
/// backoff) must get through without manual intervention, and the
/// health probe answers on the live fleet.
#[test]
fn refused_first_connects_are_retried() {
    trace_path();
    let d = synthesize("faultsc", 200, 3, 93);
    let parts = d.partition(2);
    let addrs = spawn_fault_fleet(parts, |j| {
        if j == 0 {
            FaultPlan::new().fail_connects(2)
        } else {
            FaultPlan::new()
        }
    });
    let (live, orgs, excluded) = watchdog(Duration::from_secs(60), move || {
        let mut fleet = RemoteFleet::connect(&addrs).unwrap();
        let live = fleet.ping().unwrap();
        (live, fleet.orgs(), fleet.excluded().len())
    });
    assert_eq!(live, 2, "both nodes reachable after retries");
    assert_eq!(orgs, 2);
    assert_eq!(excluded, 0);
}

/// Every fault action, against a 4-node fleet on the stats round: at
/// quorum 3 the faulty node is excluded with the right outcome
/// classification and surviving replies keep their org attribution; at
/// strict quorum the same fault fails the round naming the node.
#[test]
fn each_fault_action_excludes_at_quorum_and_fails_strict() {
    trace_path();
    let actions: [(FaultAction, &str); 4] = [
        (FaultAction::Hang, "timeout"),
        (FaultAction::DropAfterBytes(6), "timeout"),
        (FaultAction::TruncateFrame(4), "error"),
        (FaultAction::Delay(Duration::from_secs(3)), "timeout"),
    ];
    for (i, (action, want)) in actions.into_iter().enumerate() {
        let d = synthesize("faultsa", 240, 3, 94 + i as u64);
        let parts = d.partition(4);

        // Quorum 3 of 4: the round proceeds over the survivors.
        let addrs = spawn_fault_fleet(parts.clone(), |j| {
            if j == 1 {
                FaultPlan::new().on(wire::TAG_STATS_REQ, 0, action)
            } else {
                FaultPlan::new()
            }
        });
        let opts = FleetOptions {
            round_timeout: Some(Duration::from_secs(1)),
            quorum: 3,
            ..FleetOptions::default()
        };
        let faulty = addrs[1].clone();
        let (orgs_replied, n_after, excluded) = watchdog(Duration::from_secs(60), move || {
            let mut fleet = RemoteFleet::connect_with(&addrs, opts).unwrap();
            let replies = fleet.stats(&[0.0, 0.0, 0.0], 1.0 / 240.0).unwrap();
            let orgs: Vec<usize> = replies.iter().map(|r| r.org).collect();
            (orgs, fleet.n_total(), fleet.excluded().to_vec())
        });
        assert_eq!(orgs_replied, vec![0, 2, 3], "org attribution survives the exclusion");
        assert_eq!(n_after, 180, "n_total shrinks to the survivors");
        assert_eq!(excluded.len(), 1, "{excluded:?}");
        assert_eq!(excluded[0].addr, faulty, "{excluded:?}");
        assert_eq!(excluded[0].outcome, want, "{action:?} classified: {excluded:?}");
        assert_eq!((excluded[0].tag, excluded[0].round), (wire::TAG_STATS_REQ, 0));

        // Strict quorum: the same fault is a session error naming the node.
        let addrs = spawn_fault_fleet(parts, |j| {
            if j == 1 {
                FaultPlan::new().on(wire::TAG_STATS_REQ, 0, action)
            } else {
                FaultPlan::new()
            }
        });
        let opts = FleetOptions {
            round_timeout: Some(Duration::from_secs(1)),
            ..FleetOptions::default()
        };
        let faulty = addrs[1].clone();
        let err = watchdog(Duration::from_secs(60), move || {
            let mut fleet = RemoteFleet::connect_with(&addrs, opts).unwrap();
            fleet.stats(&[0.0, 0.0, 0.0], 1.0 / 240.0).unwrap_err()
        });
        let msg = err.to_string();
        assert!(msg.contains("failed mid-protocol"), "{action:?}: {msg}");
        assert!(msg.contains(&faulty), "{action:?} error names the node: {msg}");
    }
}

/// Scaling sweep: 64 node servers, one quorum stats round with 8 nodes
/// killed mid-round. The per-tag wire ledger must still partition the
/// byte totals exactly under partial replies, and the center's per-live-
/// node reply traffic must be identical to a 16-node fleet's — the
/// center's per-node footprint is flat in fleet size.
#[test]
fn scaling_sweep_64_nodes_8_killed_mid_round() {
    trace_path();
    // Shard size is fixed (8 samples, p=3) so reply frames are
    // byte-identical across fleet sizes.
    let run = |orgs: usize, kill: usize, seed: u64| -> u64 {
        let d = synthesize("faultsw", 8 * orgs, 3, seed);
        let parts = d.partition(orgs);
        let step = orgs / kill;
        let addrs = spawn_fault_fleet(parts, |j| {
            if j % step == 0 {
                FaultPlan::new().on(wire::TAG_STATS_REQ, 0, FaultAction::TruncateFrame(4))
            } else {
                FaultPlan::new()
            }
        });
        let opts = FleetOptions {
            round_timeout: Some(Duration::from_secs(2)),
            quorum: orgs - kill,
            ..FleetOptions::default()
        };
        watchdog(Duration::from_secs(120), move || {
            let mut fleet = RemoteFleet::connect_with(&addrs, opts).unwrap();
            let replies = fleet.stats(&[0.0, 0.0, 0.0], 1.0).unwrap();
            assert_eq!(replies.len(), orgs - kill);
            assert_eq!(fleet.excluded().len(), kill);
            assert_eq!(fleet.orgs(), orgs - kill);
            assert_eq!(fleet.n_total(), (orgs - kill) * 8);

            // The per-tag flows still partition the fleet byte totals
            // exactly under partial replies (the tracing PR's ledger
            // invariant).
            let net = fleet.net_stats();
            let flows = fleet.tag_flows();
            let sent: u64 = flows.values().map(|f| f.sent_bytes).sum();
            let recv: u64 = flows.values().map(|f| f.recv_bytes).sum();
            assert_eq!(net.bytes_sent, sent, "sent bytes partition by tag");
            assert_eq!(net.bytes_recv, recv, "recv bytes partition by tag");

            // Flat-footprint proxy: stats-reply bytes per live node.
            let stats = &flows[&wire::TAG_STATS_REQ];
            let live = (orgs - kill) as u64;
            assert_eq!(stats.recv_frames, live, "one stats reply per survivor");
            assert_eq!(stats.recv_bytes % live, 0);
            stats.recv_bytes / live
        })
    };
    let per_node_16 = run(16, 2, 95);
    let per_node_64 = run(64, 8, 96);
    assert_eq!(
        per_node_16, per_node_64,
        "per-live-node stats reply bytes must not grow with fleet size"
    );
}

/// A node that dies mid-frame ("killed") and whose server immediately
/// restarts: the fleet excludes it on the round it missed, then the
/// next round-boundary probe readmits it over a fresh connection —
/// `n_total` restored, attribution round-accurate in the records and in
/// the `fleet.readmit` trace span.
#[test]
fn killed_node_restarts_and_is_readmitted() {
    let trace = trace_path();
    let d = synthesize("faultsre", 240, 3, 97);
    let parts = d.partition(4);
    // Node 1's first served session dies mid-frame on its first stats
    // reply; every later session (the readmission probe's fresh
    // connection) is clean — a kill-and-restart, deterministically.
    let addrs = spawn_persistent_fleet(parts, |j| {
        if j == 1 {
            FaultPlan::new().on_session(0, wire::TAG_STATS_REQ, 0, FaultAction::TruncateFrame(4))
        } else {
            FaultPlan::new()
        }
    });
    let opts = FleetOptions {
        round_timeout: Some(Duration::from_secs(2)),
        quorum: 3,
        ..FleetOptions::default()
    };

    let killed = addrs[1].clone();
    let run_addrs = addrs.clone();
    let (n_mid, n_after, excluded, readmitted, orgs_round1) =
        watchdog(Duration::from_secs(60), move || {
            let mut fleet = RemoteFleet::connect_with(&run_addrs, opts).unwrap();
            assert_eq!(fleet.n_total(), 240);
            // Stats round 0: node 1 dies mid-frame and is excluded.
            let replies = fleet.stats(&[0.0, 0.0, 0.0], 1.0 / 240.0).unwrap();
            assert_eq!(replies.len(), 3, "round 0 proceeds over the survivors");
            let n_mid = fleet.n_total();
            // Stats round 1: the boundary probe finds the restarted
            // server, readmits it, and it participates in this round.
            let replies = fleet.stats(&[0.0, 0.0, 0.0], 1.0 / 240.0).unwrap();
            let orgs: Vec<usize> = replies.iter().map(|r| r.org).collect();
            (
                n_mid,
                fleet.n_total(),
                fleet.excluded().to_vec(),
                fleet.readmitted().to_vec(),
                orgs,
            )
        });

    assert_eq!(n_mid, 180, "n_total shrank while the node was out");
    assert_eq!(n_after, 240, "n_total restored on readmission");
    assert!(excluded.is_empty(), "readmission clears the exclusion record: {excluded:?}");
    assert_eq!(readmitted.len(), 1, "{readmitted:?}");
    assert_eq!(readmitted[0].addr, killed, "{readmitted:?}");
    assert_eq!(readmitted[0].org, 1, "{readmitted:?}");
    assert_eq!(
        (readmitted[0].tag, readmitted[0].round),
        (wire::TAG_STATS_REQ, 1),
        "readmitted at the round it first participates in: {readmitted:?}"
    );
    assert_eq!(orgs_round1, vec![0, 1, 2, 3], "the readmitted node replies in org order");

    // The trace attributes the readmission: a fleet.readmit span for
    // the killed node with outcome=ok at stats round 1.
    obs::flush();
    let file = parse_trace(&std::fs::read_to_string(trace).unwrap()).unwrap();
    let ev = file
        .events
        .iter()
        .find(|e| {
            e.span == "fleet.readmit"
                && e.node.as_deref() == Some(killed.as_str())
                && e.outcome.as_deref() == Some("ok")
        })
        .unwrap_or_else(|| panic!("no successful fleet.readmit span for {killed}"));
    assert_eq!(ev.tag, Some(wire::TAG_STATS_REQ), "{ev:?}");
    assert_eq!(ev.round, Some(1), "{ev:?}");
}

/// The acceptance scenario: a real center process SIGKILLed mid-training
/// resumes from its `--state-dir` checkpoints via `--resume`, converges
/// to the plaintext optimum, and the two incarnations stitch into one
/// logical session (same session id, global round numbering) in the
/// merged trace timeline.
#[test]
fn center_killed_mid_training_resumes_and_converges() {
    trace_path();
    // Scratch (checkpoints + per-incarnation center traces) lands under
    // PRIVLOGIT_TRACE_DIR when set, so CI can upload it as an artifact.
    let scratch = match std::env::var("PRIVLOGIT_TRACE_DIR") {
        Ok(d) if !d.is_empty() => PathBuf::from(d).join("session-recovery"),
        _ => std::env::temp_dir().join(format!("plgt-resume-{}", std::process::id())),
    };
    let _ = std::fs::remove_dir_all(&scratch);
    std::fs::create_dir_all(&scratch).unwrap();
    let state_dir = scratch.join("state");
    let trace1 = scratch.join("center-incarnation-1.jsonl");
    let trace2 = scratch.join("center-incarnation-2.jsonl");

    let d = synthesize("faultskc", 400, 3, 98);
    let parts = d.partition(4);
    let cfg = ProtocolConfig::default();
    let truth = fit(
        &parts,
        Method::Newton,
        OptimConfig { lambda: cfg.lambda, tol: cfg.tol, max_iters: cfg.max_iters },
    );
    let addrs = spawn_persistent_fleet(parts, |_| FaultPlan::new());
    let nodes = addrs.join(",");

    let center_cmd = |extra: &[&str], trace: &Path| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_privlogit"));
        cmd.args([
            "center",
            "--nodes",
            &nodes,
            "--protocol",
            "privlogit-local",
            "--backend",
            "real",
            "--modulus-bits",
            "256",
            "--seed",
            "4242",
            "--round-timeout",
            "30",
            "--json",
        ])
        .args(extra)
        .env("PRIVLOGIT_TRACE", trace)
        .env_remove("PRIVLOGIT_ROUND_TIMEOUT")
        .stdout(std::process::Stdio::piped())
        .stderr(std::process::Stdio::piped());
        cmd
    };

    // Incarnation 1: train with checkpointing until round 3 is durable,
    // then SIGKILL it mid-training.
    let state_flag = state_dir.to_str().unwrap().to_string();
    let mut child =
        center_cmd(&["--state-dir", &state_flag], &trace1).spawn().expect("spawn center");
    let deadline = std::time::Instant::now() + Duration::from_secs(120);
    let target = state_dir.join("checkpoint-000003.json");
    loop {
        if target.exists() {
            break;
        }
        if let Some(status) = child.try_wait().unwrap() {
            panic!("center exited ({status}) before checkpoint round 3 appeared");
        }
        assert!(std::time::Instant::now() < deadline, "no checkpoint round 3 within 120 s");
        std::thread::sleep(Duration::from_millis(10));
    }
    child.kill().expect("kill center");
    let _ = child.wait();

    // The latest durable checkpoint is a valid round >= 3 document.
    let cp = checkpoint::load_latest(&state_dir).unwrap().expect("a checkpoint survived the kill");
    assert!(cp.round >= 3, "latest checkpoint round {} >= 3", cp.round);
    assert_eq!(cp.protocol, "privlogit-local");
    assert_eq!(cp.epoch, 0, "first incarnation ran at epoch 0");
    let killed_at = cp.round;

    // Incarnation 2: resume from the state dir and run to convergence.
    let out = center_cmd(&["--resume", &state_flag], &trace2)
        .output()
        .expect("run resumed center");
    assert!(
        out.status.success(),
        "resumed center failed: {}\n{}",
        out.status,
        String::from_utf8_lossy(&out.stderr)
    );
    let stdout = String::from_utf8(out.stdout).unwrap();
    let report = json::parse(stdout.trim()).expect("report JSON parses");
    assert_eq!(
        report.get("converged").and_then(|v| v.as_bool()),
        Some(true),
        "resumed run converged: {stdout}"
    );
    let iterations = report.get("iterations").and_then(|v| v.as_u64()).unwrap();
    assert!(
        iterations > killed_at,
        "iteration count is global across incarnations: {iterations} vs killed at {killed_at}"
    );
    let beta: Vec<f64> = report
        .get("beta")
        .and_then(|v| v.as_arr())
        .expect("beta array")
        .iter()
        .map(|v| v.as_f64().unwrap())
        .collect();
    let r2 = r_squared(&beta, &truth.beta);
    assert!(r2 > 0.9999, "R² = {r2} vs the plaintext optimum after crash-resume");

    // The final checkpoint reflects the resumed incarnation.
    let last = checkpoint::load_latest(&state_dir).unwrap().unwrap();
    assert_eq!(last.epoch, 1, "resumed incarnation checkpoints at epoch 1");
    assert_eq!(last.round, iterations, "last checkpoint is the final round boundary");

    // Both incarnations stitch into one logical session in the merged
    // timeline: same (seed-derived) session id, and the resumed
    // incarnation's iteration spans continue the global numbering.
    // Incarnation 1 was SIGKILLed, so keep only its complete lines.
    let text1 = std::fs::read_to_string(&trace1).unwrap();
    let text1 = &text1[..text1.rfind('\n').map_or(0, |i| i + 1)];
    let file1 = parse_trace(text1).expect("incarnation 1 trace parses");
    let file2 = parse_trace(&std::fs::read_to_string(&trace2).unwrap()).unwrap();
    let sessions = |f: &privlogit::obs::timeline::TraceFile| -> std::collections::BTreeSet<String> {
        f.events.iter().map(|e| e.session.clone()).filter(|s| s != "-").collect()
    };
    let (s1, s2) = (sessions(&file1), sessions(&file2));
    assert!(!s1.is_empty() && s1 == s2, "one session id across incarnations: {s1:?} vs {s2:?}");
    let iter_rounds = |f: &privlogit::obs::timeline::TraceFile| -> Vec<u64> {
        f.events.iter().filter(|e| e.span == "proto.iter").filter_map(|e| e.round).collect()
    };
    let (r1, r2s) = (iter_rounds(&file1), iter_rounds(&file2));
    assert!(r1.contains(&0), "incarnation 1 starts at round 0: {r1:?}");
    assert!(!r2s.is_empty(), "incarnation 2 traced its iterations");
    let resumed_min = *r2s.iter().min().unwrap();
    assert!(
        resumed_min >= 3 && resumed_min <= killed_at,
        "incarnation 2 continues the global round numbering (starts at {resumed_min}, \
         killed at {killed_at})"
    );
    let timeline = Timeline::merge(vec![file1, file2]);
    assert!(
        timeline.events.iter().any(|e| e.span == "proto.iter" && e.round == Some(resumed_min)),
        "merged timeline carries the resumed incarnation's rounds"
    );
}
