//! Wire-tag exhaustiveness fixture: one orphaned tag constant and one
//! variant without round-trip coverage.

pub const TAG_PING: u8 = 0x01;
pub const TAG_PONG: u8 = 0x02;
pub const TAG_GONE: u8 = 0x03;

pub enum Msg {
    Ping,
    Pong,
}

impl Msg {
    pub fn tag(&self) -> u8 {
        match self {
            Msg::Ping => TAG_PING,
            Msg::Pong => TAG_PONG,
        }
    }
}

pub fn tag_name(tag: u8) -> &'static str {
    match tag {
        TAG_PING => "Ping",
        TAG_PONG => "Pong",
        _ => "unknown",
    }
}

#[cfg(test)]
mod tests {
    #[test]
    fn ping_round_trip() {
        let msg = super::Msg::Ping;
        assert_eq!(super::tag_name(msg.tag()), "Ping");
    }
}
