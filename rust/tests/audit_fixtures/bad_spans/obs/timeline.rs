//! Span-vocabulary fixture: the closed span list for this mini-tree.

pub const KNOWN_SPANS: &[&str] = &["proto.step"];
