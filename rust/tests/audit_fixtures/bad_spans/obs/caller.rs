//! Span/schema fixture: one unknown span and one schema version skew.

pub const SCHEMA_A: &str = "privlogit-demo/v1";
pub const SCHEMA_B: &str = "privlogit-demo/v2";

pub fn go() {
    let _guard = crate::obs::span("proto.step");
    let _other = crate::obs::span("proto.mystery");
}
