//! Deliberately leaky fixture: every way a secret type can reach a
//! Debug/Display/log surface, plus the accepted opaque idioms.

// FINDING: a secret type deriving Debug dumps its fields.
#[derive(Clone, Debug)]
pub struct PrivateKey {
    pub lambda: u64,
}

pub struct Keypair {
    pub sk: PrivateKey,
}

// FINDING: a hand-rolled Display that prints key material.
impl std::fmt::Display for Keypair {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Keypair({})", self.sk.lambda)
    }
}

// audit:secret
#[derive(Clone, Debug)]
pub struct ShareHalf {
    pub v: u64,
}

// audit:secret
pub struct BlindFactor {
    pub r: u64,
}

// An opaque impl is the accepted idiom: no finding.
impl std::fmt::Debug for BlindFactor {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("BlindFactor(<redacted>)")
    }
}

// FINDING: secret named on a log sink line.
pub fn leak(k: &PrivateKey) { crate::obs::info(format_args!("{}", k.lambda)); }

#[cfg(test)]
mod tests {
    #[test]
    fn debug_in_tests_is_fine() {
        let k = super::PrivateKey { lambda: 1 };
        let _ = format!("{k:?}");
    }
}
