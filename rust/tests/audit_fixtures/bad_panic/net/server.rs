//! Deliberately panicky fixture for the `panic-free` rule: every
//! category fires once, and both allow shapes suppress.

pub fn decode(buf: &[u8]) -> u8 {
    let first = buf.first().unwrap();
    let second = buf.get(1).expect("second byte");
    if *first == 0 {
        panic!("zero frame");
    }
    assert!(buf.len() > 2, "short frame");
    *second + buf[2]
}

// audit:allow(bogus-rule): this rule name does not exist
pub fn bad_allow(buf: &[u8]) -> u8 {
    buf.len() as u8
}

// audit:allow(panic-free): fixture fn-level suppression
pub fn covered(buf: &[u8]) -> u8 {
    buf[0]
}

pub fn line_allow(buf: &[u8]) -> u8 {
    // audit:allow(panic-free): fixture line suppression
    buf[0]
}

#[cfg(test)]
mod tests {
    #[test]
    fn unwrap_in_tests_is_fine() {
        let v = vec![1u8];
        let _ = v.first().unwrap();
    }
}
